//! A counting wrapper around any [`LinearOp`]: tallies `matvec` calls and
//! `matmat` column-work. This is how the service's cache economics (zero
//! Lanczos MVMs after the first batch on an operator) and the block solver's
//! active-column compaction (column-work strictly below
//! `iterations × columns`) are *proved* in tests rather than asserted in
//! prose.

use super::LinearOp;
use crate::linalg::{Matrix, SolveWorkspace};
use std::sync::atomic::{AtomicU64, Ordering};

/// Wraps a [`LinearOp`] and counts the work flowing through it.
///
/// `matvec` and `matmat` are the two paid entry points: eigenvalue estimation
/// (Lanczos) spends `matvec`s, blocked msMINRES spends `matmat` columns.
/// Probe-style accessors (`diagonal`, `column`, `to_dense`) delegate without
/// counting — they are test/setup conveniences, not hot-path work.
pub struct CountingOp<T> {
    inner: T,
    matvecs: AtomicU64,
    matmats: AtomicU64,
    matmat_cols: AtomicU64,
}

impl<T: LinearOp> CountingOp<T> {
    /// Wrap an operator with fresh counters.
    pub fn new(inner: T) -> CountingOp<T> {
        CountingOp {
            inner,
            matvecs: AtomicU64::new(0),
            matmats: AtomicU64::new(0),
            matmat_cols: AtomicU64::new(0),
        }
    }

    /// Number of `matvec` calls so far (Lanczos estimation spends these).
    pub fn matvec_count(&self) -> u64 {
        // ordering: Relaxed — work counter; tests read it after the counted
        // work has already been synchronized by join/channel receipt.
        self.matvecs.load(Ordering::Relaxed)
    }

    /// Number of `matmat` calls so far (one per block-solver iteration).
    pub fn matmat_count(&self) -> u64 {
        // ordering: Relaxed — same work-counter discipline as `matvec_count`.
        self.matmats.load(Ordering::Relaxed)
    }

    /// Total columns across all `matmat` calls — the block solver's true
    /// column-work.
    pub fn matmat_col_count(&self) -> u64 {
        // ordering: Relaxed — same work-counter discipline as `matvec_count`.
        self.matmat_cols.load(Ordering::Relaxed)
    }

    /// Reset all counters to zero.
    pub fn reset(&self) {
        // ordering: Relaxed — counters are independent; callers reset between
        // phases, never concurrently with counted work they care about.
        self.matvecs.store(0, Ordering::Relaxed);
        self.matmats.store(0, Ordering::Relaxed);
        self.matmat_cols.store(0, Ordering::Relaxed);
    }

    /// The wrapped operator.
    pub fn inner(&self) -> &T {
        &self.inner
    }
}

impl<T: LinearOp> LinearOp for CountingOp<T> {
    fn size(&self) -> usize {
        self.inner.size()
    }

    fn matvec(&self, x: &[f64]) -> Vec<f64> {
        // ordering: Relaxed — tally only; no data is published through it.
        self.matvecs.fetch_add(1, Ordering::Relaxed);
        self.inner.matvec(x)
    }

    fn matvec_in(&self, ws: &mut SolveWorkspace, x: &[f64], out: &mut [f64]) {
        // ordering: Relaxed — tally only; no data is published through it.
        self.matvecs.fetch_add(1, Ordering::Relaxed);
        self.inner.matvec_in(ws, x, out)
    }

    fn matmat(&self, x: &Matrix) -> Matrix {
        // ordering: Relaxed — tallies only; no data is published through them.
        self.matmats.fetch_add(1, Ordering::Relaxed);
        self.matmat_cols.fetch_add(x.cols() as u64, Ordering::Relaxed);
        self.inner.matmat(x)
    }

    fn matmat_in(&self, ws: &mut SolveWorkspace, x: &Matrix, out: &mut Matrix) {
        // ordering: Relaxed — tallies only; no data is published through them.
        self.matmats.fetch_add(1, Ordering::Relaxed);
        self.matmat_cols.fetch_add(x.cols() as u64, Ordering::Relaxed);
        self.inner.matmat_in(ws, x, out)
    }

    fn supports_mixed(&self) -> bool {
        self.inner.supports_mixed()
    }

    fn matmat_mixed_in(&self, ws: &mut SolveWorkspace, x: &Matrix, out: &mut Matrix) {
        // Mixed MVMs are paid hot-path work just like f64 ones — count them
        // in the same tallies so MVM-budget tests hold under either policy.
        // ordering: Relaxed — tallies only; no data is published through them.
        self.matmats.fetch_add(1, Ordering::Relaxed);
        self.matmat_cols.fetch_add(x.cols() as u64, Ordering::Relaxed);
        self.inner.matmat_mixed_in(ws, x, out)
    }

    fn diagonal(&self) -> Vec<f64> {
        self.inner.diagonal()
    }

    fn column(&self, j: usize) -> Vec<f64> {
        self.inner.column(j)
    }

    fn lambda_min_bound(&self) -> Option<f64> {
        self.inner.lambda_min_bound()
    }

    fn to_dense(&self) -> Matrix {
        self.inner.to_dense()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::krylov::msminres::{msminres_block, MsMinresOptions};
    use crate::operators::DenseOp;
    use crate::rng::Pcg64;

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seeded(seed);
        let a = Matrix::randn(n, n, &mut rng);
        let mut k = a.matmul(&a.transpose());
        for i in 0..n {
            k[(i, i)] += n as f64 * 0.5;
        }
        k
    }

    #[test]
    fn counts_matvecs_and_matmat_columns() {
        let op = CountingOp::new(DenseOp::new(spd(6, 1)));
        let x = vec![1.0; 6];
        let _ = op.matvec(&x);
        let _ = op.matvec(&x);
        let mut rng = Pcg64::seeded(2);
        let b = Matrix::randn(6, 3, &mut rng);
        let _ = op.matmat(&b);
        assert_eq!(op.matvec_count(), 2);
        assert_eq!(op.matmat_count(), 1);
        assert_eq!(op.matmat_col_count(), 3);
        // probes are not counted as hot-path work
        let _ = op.diagonal();
        let _ = op.column(0);
        assert_eq!(op.matvec_count(), 2);
        op.reset();
        assert_eq!(op.matvec_count(), 0);
        assert_eq!(op.matmat_col_count(), 0);
        assert_eq!(op.inner().size(), 6);
    }

    #[test]
    fn block_solver_column_work_matches_operator_counter() {
        // The compaction counter reported by msminres_block must equal the
        // matmat columns the operator actually served.
        let n = 30;
        let op = CountingOp::new(DenseOp::new(spd(n, 3)));
        let mut rng = Pcg64::seeded(4);
        let b = Matrix::randn(n, 3, &mut rng);
        let opts = MsMinresOptions { max_iters: 200, tol: 1e-9, weights: None };
        let res = msminres_block(&op, &b, &[0.1, 1.0], &opts);
        assert_eq!(op.matmat_col_count(), res.column_work as u64);
        assert_eq!(op.matvec_count(), 0, "block solver must never fall back to matvec");
    }
}

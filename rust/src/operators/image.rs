//! Image-space linear operators for the Gibbs-sampling super-resolution
//! experiment (Sec. 5.3): Gaussian blur `B`, decimation `D`, discrete
//! Laplacian `L` (Eq. S26) — all with reflected (non-periodic) boundaries
//! and exact adjoints — plus the posterior precision operator
//! `Λ = γ_obs AᵀA + γ_prior LᵀL` with `A = D B` stacked over `R`
//! low-resolution observations.

use super::LinearOp;

/// Small 2-D convolution with reflected boundaries and an exact adjoint.
#[derive(Clone)]
pub struct Conv2d {
    /// image side length (operates on n×n images flattened row-major)
    n: usize,
    /// filter taps, row-major, `size × size` with odd `size`
    taps: Vec<f64>,
    size: usize,
}

/// Reflect index into `[0, n)` (non-periodic, edge-mirrored).
#[inline]
fn reflect(i: isize, n: usize) -> usize {
    let n = n as isize;
    let mut i = i;
    // handles any |i| < 2n, which covers our small filters
    if i < 0 {
        i = -i - 1;
    }
    if i >= n {
        i = 2 * n - 1 - i;
    }
    debug_assert!(i >= 0 && i < n);
    i as usize
}

impl Conv2d {
    /// Build from explicit taps (`size` odd).
    pub fn new(n: usize, taps: Vec<f64>, size: usize) -> Conv2d {
        assert_eq!(taps.len(), size * size);
        assert!(size % 2 == 1);
        Conv2d { n, taps, size }
    }

    /// Gaussian blur with std `sigma` pixels, truncated to `size` taps
    /// (paper: radius 2.5, size 5), normalized to sum 1.
    pub fn gaussian_blur(n: usize, sigma: f64, size: usize) -> Conv2d {
        assert!(size % 2 == 1);
        let half = (size / 2) as isize;
        let mut taps = Vec::with_capacity(size * size);
        for dy in -half..=half {
            for dx in -half..=half {
                let r2 = (dx * dx + dy * dy) as f64;
                taps.push((-r2 / (2.0 * sigma * sigma)).exp());
            }
        }
        let s: f64 = taps.iter().sum();
        for t in &mut taps {
            *t /= s;
        }
        Conv2d::new(n, taps, size)
    }

    /// Discrete isotropic Laplacian of Eq. (S26).
    pub fn laplacian(n: usize) -> Conv2d {
        let taps = vec![
            1.0 / 12.0, 2.0 / 12.0, 1.0 / 12.0,
            2.0 / 12.0, -12.0 / 12.0, 2.0 / 12.0,
            1.0 / 12.0, 2.0 / 12.0, 1.0 / 12.0,
        ];
        Conv2d::new(n, taps, 3)
    }

    /// Image side length.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Forward convolution (gather with reflected boundary).
    pub fn apply(&self, x: &[f64]) -> Vec<f64> {
        let n = self.n;
        assert_eq!(x.len(), n * n);
        let half = (self.size / 2) as isize;
        let mut out = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0.0;
                let mut t = 0;
                for dy in -half..=half {
                    let ii = reflect(i as isize + dy, n);
                    for dx in -half..=half {
                        let jj = reflect(j as isize + dx, n);
                        acc += self.taps[t] * x[ii * n + jj];
                        t += 1;
                    }
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    /// Exact adjoint (scatter with the same boundary handling).
    pub fn apply_adjoint(&self, y: &[f64]) -> Vec<f64> {
        let n = self.n;
        assert_eq!(y.len(), n * n);
        let half = (self.size / 2) as isize;
        let mut out = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let v = y[i * n + j];
                if v == 0.0 {
                    continue;
                }
                let mut t = 0;
                for dy in -half..=half {
                    let ii = reflect(i as isize + dy, n);
                    for dx in -half..=half {
                        let jj = reflect(j as isize + dx, n);
                        out[ii * n + jj] += self.taps[t] * v;
                        t += 1;
                    }
                }
            }
        }
        out
    }
}

/// Block-average decimation from `n×n` down to `m×m` (`n = f·m`).
#[derive(Clone)]
pub struct Downsample {
    n: usize,
    m: usize,
    f: usize,
}

impl Downsample {
    /// Build an `n → n/factor` decimator.
    pub fn new(n: usize, factor: usize) -> Downsample {
        assert!(factor >= 1 && n % factor == 0, "n must be divisible by factor");
        Downsample { n, m: n / factor, f: factor }
    }

    /// Low-res side length.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Forward: average each f×f block.
    pub fn apply(&self, x: &[f64]) -> Vec<f64> {
        let (n, m, f) = (self.n, self.m, self.f);
        assert_eq!(x.len(), n * n);
        let norm = 1.0 / (f * f) as f64;
        let mut out = vec![0.0; m * m];
        for bi in 0..m {
            for bj in 0..m {
                let mut acc = 0.0;
                for di in 0..f {
                    let row = (bi * f + di) * n + bj * f;
                    for dj in 0..f {
                        acc += x[row + dj];
                    }
                }
                out[bi * m + bj] = acc * norm;
            }
        }
        out
    }

    /// Adjoint: spread each low-res value uniformly over its block.
    pub fn apply_adjoint(&self, y: &[f64]) -> Vec<f64> {
        let (n, m, f) = (self.n, self.m, self.f);
        assert_eq!(y.len(), m * m);
        let norm = 1.0 / (f * f) as f64;
        let mut out = vec![0.0; n * n];
        for bi in 0..m {
            for bj in 0..m {
                let v = y[bi * m + bj] * norm;
                for di in 0..f {
                    let row = (bi * f + di) * n + bj * f;
                    for dj in 0..f {
                        out[row + dj] += v;
                    }
                }
            }
        }
        out
    }
}

/// Posterior precision `Λ = γ_obs · R · BᵀDᵀD B + γ_prior · LᵀL` of the
/// super-resolution model (the `R` identical observation operators stack
/// into a factor of `R` on the data term).
pub struct PrecisionOp {
    blur: Conv2d,
    down: Downsample,
    lap: Conv2d,
    /// number of low-resolution observations R
    pub r: usize,
    /// observation precision γ_obs
    pub gamma_obs: f64,
    /// prior precision γ_prior
    pub gamma_prior: f64,
}

impl PrecisionOp {
    /// Build for an `n×n` latent image, decimation `factor`, `r` low-res
    /// observations and hyperparameters `(γ_obs, γ_prior)`.
    pub fn new(n: usize, factor: usize, r: usize, gamma_obs: f64, gamma_prior: f64) -> PrecisionOp {
        PrecisionOp {
            blur: Conv2d::gaussian_blur(n, 2.5, 5),
            down: Downsample::new(n, factor),
            lap: Conv2d::laplacian(n),
            r,
            gamma_obs,
            gamma_prior,
        }
    }

    /// Forward observation map `A x = D(B(x))` (one replicate).
    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        self.down.apply(&self.blur.apply(x))
    }

    /// Adjoint observation map `Aᵀ y = Bᵀ(Dᵀ(y))` (one replicate).
    pub fn adjoint(&self, y: &[f64]) -> Vec<f64> {
        self.blur.apply_adjoint(&self.down.apply_adjoint(y))
    }

    /// `‖L x‖²` — the prior quadratic form used by the γ_prior conditional.
    pub fn prior_quad(&self, x: &[f64]) -> f64 {
        self.lap.apply(x).iter().map(|v| v * v).sum()
    }

    /// Access the blur operator.
    pub fn blur(&self) -> &Conv2d {
        &self.blur
    }

    /// Access the decimator.
    pub fn down(&self) -> &Downsample {
        &self.down
    }
}

impl LinearOp for PrecisionOp {
    fn size(&self) -> usize {
        self.blur.n() * self.blur.n()
    }

    fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let data = self.adjoint(&self.forward(x));
        let lap2 = self.lap.apply_adjoint(&self.lap.apply(x));
        let c_obs = self.gamma_obs * self.r as f64;
        data.iter()
            .zip(&lap2)
            .map(|(d, l)| c_obs * d + self.gamma_prior * l)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::util::dot;

    #[test]
    fn reflect_indexing() {
        assert_eq!(reflect(-1, 5), 0);
        assert_eq!(reflect(-2, 5), 1);
        assert_eq!(reflect(0, 5), 0);
        assert_eq!(reflect(4, 5), 4);
        assert_eq!(reflect(5, 5), 4);
        assert_eq!(reflect(6, 5), 3);
    }

    #[test]
    fn blur_preserves_constants_and_mass() {
        let n = 12;
        let blur = Conv2d::gaussian_blur(n, 2.5, 5);
        let ones = vec![1.0; n * n];
        let out = blur.apply(&ones);
        for &v in &out {
            assert!((v - 1.0).abs() < 1e-12, "blur must preserve constants, got {v}");
        }
    }

    #[test]
    fn adjoint_is_true_adjoint() {
        // <Ax, y> == <x, Aᵀy> for random x, y — for blur, laplacian, downsample
        let n = 10;
        let mut rng = Pcg64::seeded(1);
        let x: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        for conv in [Conv2d::gaussian_blur(n, 2.5, 5), Conv2d::laplacian(n)] {
            let y: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
            let lhs = dot(&conv.apply(&x), &y);
            let rhs = dot(&x, &conv.apply_adjoint(&y));
            assert!((lhs - rhs).abs() < 1e-10, "conv adjoint mismatch {lhs} vs {rhs}");
        }
        let ds = Downsample::new(n, 2);
        let y: Vec<f64> = (0..(n / 2) * (n / 2)).map(|_| rng.normal()).collect();
        let lhs = dot(&ds.apply(&x), &y);
        let rhs = dot(&x, &ds.apply_adjoint(&y));
        assert!((lhs - rhs).abs() < 1e-10, "downsample adjoint mismatch");
    }

    #[test]
    fn laplacian_kills_constants() {
        let n = 8;
        let lap = Conv2d::laplacian(n);
        let ones = vec![3.0; n * n];
        let out = lap.apply(&ones);
        for &v in &out {
            assert!(v.abs() < 1e-12);
        }
    }

    #[test]
    fn downsample_averages() {
        let n = 4;
        let ds = Downsample::new(n, 2);
        let x: Vec<f64> = (0..16).map(|i| i as f64).collect();
        let y = ds.apply(&x);
        // block (0,0): 0,1,4,5 -> 2.5
        assert!((y[0] - 2.5).abs() < 1e-12);
        assert!((y[1] - 4.5).abs() < 1e-12);
        assert!((y[2] - 10.5).abs() < 1e-12);
        assert!((y[3] - 12.5).abs() < 1e-12);
    }

    #[test]
    fn precision_op_is_symmetric_psd() {
        let n = 8;
        let op = PrecisionOp::new(n, 2, 3, 1.0, 0.5);
        let mut rng = Pcg64::seeded(2);
        // symmetry: <Λx, y> == <x, Λy>
        let x: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let lhs = dot(&op.matvec(&x), &y);
        let rhs = dot(&x, &op.matvec(&y));
        assert!((lhs - rhs).abs() < 1e-9 * lhs.abs().max(1.0));
        // PSD: xᵀΛx >= 0
        for _ in 0..5 {
            let x: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
            let q = dot(&x, &op.matvec(&x));
            assert!(q >= -1e-10, "quadratic form negative: {q}");
        }
        // strictly PD on constants thanks to the data term
        let c = vec![1.0; n * n];
        let q = dot(&c, &op.matvec(&c));
        assert!(q > 1e-6);
    }
}

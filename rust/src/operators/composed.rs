//! Operator combinators: shifts, scaling, sums, diagonals, low-rank updates.
//!
//! Every combinator carries both a fused blocked [`LinearOp::matmat`] (so
//! batch economics survive composition) and a workspace-fed
//! [`LinearOp::matmat_in`] that draws its panel scratch from the caller's
//! [`SolveWorkspace`] instead of allocating — composition therefore
//! preserves the solve stack's zero-allocation steady state.

use super::LinearOp;
use crate::linalg::{Matrix, SolveWorkspace};

/// `K + t I` — the shifted systems at the heart of msMINRES-CIQ.
pub struct ShiftedOp<'a, T: LinearOp + ?Sized> {
    inner: &'a T,
    shift: f64,
}

impl<'a, T: LinearOp + ?Sized> ShiftedOp<'a, T> {
    /// Wrap `inner + shift·I`.
    pub fn new(inner: &'a T, shift: f64) -> Self {
        ShiftedOp { inner, shift }
    }
}

impl<T: LinearOp + ?Sized> LinearOp for ShiftedOp<'_, T> {
    fn size(&self) -> usize {
        self.inner.size()
    }
    fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = self.inner.matvec(x);
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += self.shift * xi;
        }
        y
    }
    fn matmat(&self, x: &Matrix) -> Matrix {
        // forward the whole block to the inner operator so a structured inner
        // (e.g. the panel-GEMM kernel engine) keeps its batched economics
        let mut y = self.inner.matmat(x);
        for (yi, xi) in y.as_mut_slice().iter_mut().zip(x.as_slice()) {
            *yi += self.shift * xi;
        }
        y
    }
    fn matvec_in(&self, ws: &mut SolveWorkspace, x: &[f64], out: &mut [f64]) {
        self.inner.matvec_in(ws, x, out);
        for (yi, xi) in out.iter_mut().zip(x) {
            *yi += self.shift * xi;
        }
    }
    fn matmat_in(&self, ws: &mut SolveWorkspace, x: &Matrix, out: &mut Matrix) {
        self.inner.matmat_in(ws, x, out);
        for (yi, xi) in out.as_mut_slice().iter_mut().zip(x.as_slice()) {
            *yi += self.shift * xi;
        }
    }
    fn diagonal(&self) -> Vec<f64> {
        let mut d = self.inner.diagonal();
        for di in &mut d {
            *di += self.shift;
        }
        d
    }
    fn lambda_min_bound(&self) -> Option<f64> {
        self.inner.lambda_min_bound().map(|b| b + self.shift)
    }
}

/// `c · K`.
pub struct ScaledOp<'a, T: LinearOp + ?Sized> {
    inner: &'a T,
    scale: f64,
}

impl<'a, T: LinearOp + ?Sized> ScaledOp<'a, T> {
    /// Wrap `scale · inner`.
    pub fn new(inner: &'a T, scale: f64) -> Self {
        ScaledOp { inner, scale }
    }
}

impl<T: LinearOp + ?Sized> LinearOp for ScaledOp<'_, T> {
    fn size(&self) -> usize {
        self.inner.size()
    }
    fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = self.inner.matvec(x);
        for yi in &mut y {
            *yi *= self.scale;
        }
        y
    }
    fn matmat(&self, x: &Matrix) -> Matrix {
        let mut y = self.inner.matmat(x);
        y.scale(self.scale);
        y
    }
    fn matvec_in(&self, ws: &mut SolveWorkspace, x: &[f64], out: &mut [f64]) {
        self.inner.matvec_in(ws, x, out);
        for yi in out.iter_mut() {
            *yi *= self.scale;
        }
    }
    fn matmat_in(&self, ws: &mut SolveWorkspace, x: &Matrix, out: &mut Matrix) {
        self.inner.matmat_in(ws, x, out);
        out.scale(self.scale);
    }
    fn diagonal(&self) -> Vec<f64> {
        self.inner.diagonal().into_iter().map(|d| d * self.scale).collect()
    }
}

/// `A + B` of two operators of equal size.
pub struct SumOp<'a> {
    a: &'a dyn LinearOp,
    b: &'a dyn LinearOp,
    wa: f64,
    wb: f64,
}

impl<'a> SumOp<'a> {
    /// `wa·A + wb·B`.
    pub fn new(a: &'a dyn LinearOp, wa: f64, b: &'a dyn LinearOp, wb: f64) -> Self {
        assert_eq!(a.size(), b.size(), "SumOp size mismatch");
        SumOp { a, b, wa, wb }
    }
}

impl LinearOp for SumOp<'_> {
    fn size(&self) -> usize {
        self.a.size()
    }
    fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let ya = self.a.matvec(x);
        let yb = self.b.matvec(x);
        ya.iter().zip(&yb).map(|(p, q)| self.wa * p + self.wb * q).collect()
    }
    fn matmat(&self, x: &Matrix) -> Matrix {
        let mut ya = self.a.matmat(x);
        let yb = self.b.matmat(x);
        for (p, q) in ya.as_mut_slice().iter_mut().zip(yb.as_slice()) {
            *p = self.wa * *p + self.wb * q;
        }
        ya
    }
    fn matvec_in(&self, ws: &mut SolveWorkspace, x: &[f64], out: &mut [f64]) {
        self.a.matvec_in(ws, x, out);
        let mut yb = ws.take_vec(self.size());
        self.b.matvec_in(ws, x, &mut yb);
        for (p, q) in out.iter_mut().zip(&yb) {
            *p = self.wa * *p + self.wb * q;
        }
        ws.give_vec(yb);
    }
    fn matmat_in(&self, ws: &mut SolveWorkspace, x: &Matrix, out: &mut Matrix) {
        self.a.matmat_in(ws, x, out);
        let mut yb = ws.take_mat(self.size(), x.cols());
        self.b.matmat_in(ws, x, &mut yb);
        for (p, q) in out.as_mut_slice().iter_mut().zip(yb.as_slice()) {
            *p = self.wa * *p + self.wb * q;
        }
        ws.give_mat(yb);
    }
    fn diagonal(&self) -> Vec<f64> {
        let da = self.a.diagonal();
        let db = self.b.diagonal();
        da.iter().zip(&db).map(|(p, q)| self.wa * p + self.wb * q).collect()
    }
}

/// Diagonal operator.
pub struct DiagOp {
    d: Vec<f64>,
}

impl DiagOp {
    /// Wrap a diagonal.
    pub fn new(d: Vec<f64>) -> DiagOp {
        DiagOp { d }
    }
}

impl LinearOp for DiagOp {
    fn size(&self) -> usize {
        self.d.len()
    }
    fn matvec(&self, x: &[f64]) -> Vec<f64> {
        self.d.iter().zip(x).map(|(d, x)| d * x).collect()
    }
    fn matmat(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.rows(), self.size(), "matmat dim mismatch");
        let mut y = x.clone();
        for (i, &d) in self.d.iter().enumerate() {
            for v in y.row_mut(i) {
                *v *= d;
            }
        }
        y
    }
    fn matvec_in(&self, _ws: &mut SolveWorkspace, x: &[f64], out: &mut [f64]) {
        for ((o, &d), &xi) in out.iter_mut().zip(&self.d).zip(x) {
            *o = d * xi;
        }
    }
    fn matmat_in(&self, _ws: &mut SolveWorkspace, x: &Matrix, out: &mut Matrix) {
        assert_eq!(x.rows(), self.size(), "matmat dim mismatch");
        out.as_mut_slice().copy_from_slice(x.as_slice());
        for (i, &d) in self.d.iter().enumerate() {
            for v in out.row_mut(i) {
                *v *= d;
            }
        }
    }
    fn diagonal(&self) -> Vec<f64> {
        self.d.clone()
    }
}

/// `L Lᵀ + σ² I` for a tall-skinny `L` (`n × r`) — the pivoted-Cholesky
/// preconditioner's shape. MVM is `O(nr)`.
pub struct LowRankPlusDiagOp {
    l: Matrix,
    sigma2: f64,
}

impl LowRankPlusDiagOp {
    /// Wrap `L Lᵀ + σ² I`.
    pub fn new(l: Matrix, sigma2: f64) -> Self {
        assert!(sigma2 >= 0.0);
        LowRankPlusDiagOp { l, sigma2 }
    }

    /// The low-rank factor.
    pub fn factor(&self) -> &Matrix {
        &self.l
    }

    /// σ².
    pub fn sigma2(&self) -> f64 {
        self.sigma2
    }
}

impl LinearOp for LowRankPlusDiagOp {
    fn size(&self) -> usize {
        self.l.rows()
    }
    fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let lt_x = self.l.matvec_t(x);
        let mut y = self.l.matvec(&lt_x);
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += self.sigma2 * xi;
        }
        y
    }
    fn matmat(&self, x: &Matrix) -> Matrix {
        let lt_x = self.l.t_matmul(x);
        let mut y = self.l.matmul(&lt_x);
        for (yi, xi) in y.as_mut_slice().iter_mut().zip(x.as_slice()) {
            *yi += self.sigma2 * xi;
        }
        y
    }
    fn matvec_in(&self, ws: &mut SolveWorkspace, x: &[f64], out: &mut [f64]) {
        let mut lt_x = ws.take_vec(self.l.cols());
        self.l.matvec_t_into(x, &mut lt_x);
        self.l.matvec_into(&lt_x, out);
        for (yi, xi) in out.iter_mut().zip(x) {
            *yi += self.sigma2 * xi;
        }
        ws.give_vec(lt_x);
    }
    fn matmat_in(&self, ws: &mut SolveWorkspace, x: &Matrix, out: &mut Matrix) {
        let mut lt_x = ws.take_mat(self.l.cols(), x.cols());
        self.l.t_matmul_in(ws, x, &mut lt_x);
        self.l.matmul_into(&lt_x, out);
        for (yi, xi) in out.as_mut_slice().iter_mut().zip(x.as_slice()) {
            *yi += self.sigma2 * xi;
        }
        ws.give_mat(lt_x);
    }
    fn diagonal(&self) -> Vec<f64> {
        (0..self.size())
            .map(|i| self.l.row(i).iter().map(|v| v * v).sum::<f64>() + self.sigma2)
            .collect()
    }
}

/// `A − W Wᵀ` for tall-skinny `W` — GP posterior covariance at candidate
/// points: `K** − K*n (Knn+σ²)⁻¹ Kn*` with `W = K*n L⁻ᵀ`. MVM is
/// `O(MVM(A) + n·r)`, memory `O(n·r)`.
pub struct SubtractLowRankOp<'a> {
    a: &'a dyn LinearOp,
    w: Matrix,
    lam_min: Option<f64>,
}

impl<'a> SubtractLowRankOp<'a> {
    /// Wrap `A − W Wᵀ`. Caller guarantees positive (semi-)definiteness.
    pub fn new(a: &'a dyn LinearOp, w: Matrix) -> Self {
        assert_eq!(a.size(), w.rows(), "SubtractLowRankOp size mismatch");
        SubtractLowRankOp { a, w, lam_min: None }
    }

    /// Declare a λ_min lower bound the *caller* can certify — e.g. for a GP
    /// posterior covariance `(K** + jitter·I) − W Wᵀ` where `K** − W Wᵀ` is a
    /// Schur complement (PSD), so λ_min ≥ jitter.
    pub fn with_lambda_min_bound(mut self, bound: f64) -> Self {
        self.lam_min = Some(bound);
        self
    }
}

impl LinearOp for SubtractLowRankOp<'_> {
    fn size(&self) -> usize {
        self.a.size()
    }
    fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = self.a.matvec(x);
        let wt_x = self.w.matvec_t(x);
        let wwt_x = self.w.matvec(&wt_x);
        for (yi, wi) in y.iter_mut().zip(&wwt_x) {
            *yi -= wi;
        }
        y
    }
    fn matmat(&self, x: &Matrix) -> Matrix {
        let mut y = self.a.matmat(x);
        let wt_x = self.w.t_matmul(x);
        let wwt_x = self.w.matmul(&wt_x);
        for (yi, wi) in y.as_mut_slice().iter_mut().zip(wwt_x.as_slice()) {
            *yi -= wi;
        }
        y
    }
    fn matvec_in(&self, ws: &mut SolveWorkspace, x: &[f64], out: &mut [f64]) {
        self.a.matvec_in(ws, x, out);
        let mut wt_x = ws.take_vec(self.w.cols());
        self.w.matvec_t_into(x, &mut wt_x);
        let mut wwt_x = ws.take_vec(self.size());
        self.w.matvec_into(&wt_x, &mut wwt_x);
        for (yi, wi) in out.iter_mut().zip(&wwt_x) {
            *yi -= wi;
        }
        ws.give_vec(wt_x);
        ws.give_vec(wwt_x);
    }
    fn matmat_in(&self, ws: &mut SolveWorkspace, x: &Matrix, out: &mut Matrix) {
        self.a.matmat_in(ws, x, out);
        let mut wt_x = ws.take_mat(self.w.cols(), x.cols());
        self.w.t_matmul_in(ws, x, &mut wt_x);
        let mut wwt_x = ws.take_mat(self.size(), x.cols());
        self.w.matmul_into(&wt_x, &mut wwt_x);
        for (yi, wi) in out.as_mut_slice().iter_mut().zip(wwt_x.as_slice()) {
            *yi -= wi;
        }
        ws.give_mat(wt_x);
        ws.give_mat(wwt_x);
    }
    fn diagonal(&self) -> Vec<f64> {
        let da = self.a.diagonal();
        (0..self.size())
            .map(|i| da[i] - self.w.row(i).iter().map(|v| v * v).sum::<f64>())
            .collect()
    }
    fn lambda_min_bound(&self) -> Option<f64> {
        self.lam_min
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operators::DenseOp;
    use crate::rng::Pcg64;

    fn sym(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seeded(seed);
        let mut a = Matrix::randn(n, n, &mut rng);
        a.symmetrize();
        a
    }

    #[test]
    fn shifted_and_scaled() {
        let a = sym(10, 1);
        let op = DenseOp::new(a.clone());
        let sh = ShiftedOp::new(&op, 2.5);
        let mut rng = Pcg64::seeded(2);
        let x: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        let y = sh.matvec(&x);
        let mut expect = a.matvec(&x);
        for (e, xi) in expect.iter_mut().zip(&x) {
            *e += 2.5 * xi;
        }
        for (u, v) in y.iter().zip(&expect) {
            assert!((u - v).abs() < 1e-12);
        }
        let sc = ScaledOp::new(&op, -0.5);
        let z = sc.matvec(&x);
        let az = a.matvec(&x);
        for (u, v) in z.iter().zip(&az) {
            assert!((u + 0.5 * v).abs() < 1e-12);
        }
    }

    #[test]
    fn sum_and_diag_ops() {
        let a = sym(8, 3);
        let b = sym(8, 4);
        let (oa, ob) = (DenseOp::new(a.clone()), DenseOp::new(b.clone()));
        let s = SumOp::new(&oa, 2.0, &ob, 3.0);
        let mut rng = Pcg64::seeded(5);
        let x: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
        let y = s.matvec(&x);
        let ya = a.matvec(&x);
        let yb = b.matvec(&x);
        for i in 0..8 {
            assert!((y[i] - (2.0 * ya[i] + 3.0 * yb[i])).abs() < 1e-12);
        }
        let d = DiagOp::new(vec![1.0, 2.0, 3.0]);
        assert_eq!(d.matvec(&[1.0, 1.0, 1.0]), vec![1.0, 2.0, 3.0]);
        assert_eq!(d.diagonal(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn lowrank_plus_diag_matches_dense() {
        let mut rng = Pcg64::seeded(6);
        let l = Matrix::randn(12, 3, &mut rng);
        let op = LowRankPlusDiagOp::new(l.clone(), 0.7);
        let dense = {
            let mut m = l.matmul(&l.transpose());
            for i in 0..12 {
                m[(i, i)] += 0.7;
            }
            m
        };
        assert!(op.to_dense().max_abs_diff(&dense) < 1e-12);
        let d = op.diagonal();
        for i in 0..12 {
            assert!((d[i] - dense[(i, i)]).abs() < 1e-12);
        }
    }

    /// Oracle: the trait's default per-column matmat (what the combinators
    /// used before gaining fused blocked overrides).
    fn matmat_by_columns(op: &dyn LinearOp, x: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(op.size(), x.cols());
        for j in 0..x.cols() {
            let y = op.matvec(&x.col(j));
            for i in 0..op.size() {
                out[(i, j)] = y[i];
            }
        }
        out
    }

    #[test]
    fn combinator_matmat_overrides_match_per_column() {
        let mut rng = Pcg64::seeded(9);
        let base = sym(14, 10);
        let other = sym(14, 11);
        let op_a = DenseOp::new(base);
        let op_b = DenseOp::new(other);
        let x = Matrix::randn(14, 5, &mut rng);
        let w = Matrix::randn(14, 3, &mut rng);
        let l = Matrix::randn(14, 4, &mut rng);

        let shifted = ShiftedOp::new(&op_a, 1.7);
        assert!(shifted.matmat(&x).max_abs_diff(&matmat_by_columns(&shifted, &x)) < 1e-12);
        let scaled = ScaledOp::new(&op_a, -0.3);
        assert!(scaled.matmat(&x).max_abs_diff(&matmat_by_columns(&scaled, &x)) < 1e-12);
        let sum = SumOp::new(&op_a, 0.5, &op_b, 2.0);
        assert!(sum.matmat(&x).max_abs_diff(&matmat_by_columns(&sum, &x)) < 1e-12);
        let diag = DiagOp::new((0..14).map(|i| 0.5 + i as f64).collect());
        assert!(diag.matmat(&x).max_abs_diff(&matmat_by_columns(&diag, &x)) < 1e-12);
        let lr = LowRankPlusDiagOp::new(l, 0.9);
        assert!(lr.matmat(&x).max_abs_diff(&matmat_by_columns(&lr, &x)) < 1e-12);
        let sub = SubtractLowRankOp::new(&op_a, w);
        assert!(sub.matmat(&x).max_abs_diff(&matmat_by_columns(&sub, &x)) < 1e-12);
    }

    #[test]
    fn combinator_workspace_variants_match_and_stay_warm() {
        // matmat_in/matvec_in must agree with their allocating twins and
        // perform zero workspace growth once warmed.
        let mut rng = Pcg64::seeded(13);
        let mut ws = crate::linalg::SolveWorkspace::new();
        let base = sym(14, 14);
        let other = sym(14, 15);
        let op_a = DenseOp::new(base);
        let op_b = DenseOp::new(other);
        let x = Matrix::randn(14, 5, &mut rng);
        let xv: Vec<f64> = (0..14).map(|_| rng.normal()).collect();
        let w = Matrix::randn(14, 3, &mut rng);
        let l = Matrix::randn(14, 4, &mut rng);
        let shifted = ShiftedOp::new(&op_a, 1.7);
        let scaled = ScaledOp::new(&op_a, -0.3);
        let sum = SumOp::new(&op_a, 0.5, &op_b, 2.0);
        let diag = DiagOp::new((0..14).map(|i| 0.5 + i as f64).collect());
        let lr = LowRankPlusDiagOp::new(l, 0.9);
        let sub = SubtractLowRankOp::new(&op_a, w);
        let ops: [&dyn LinearOp; 6] = [&shifted, &scaled, &sum, &diag, &lr, &sub];
        for _round in 0..2 {
            for op in ops {
                let want = op.matmat(&x);
                let mut out = ws.take_mat(14, 5);
                op.matmat_in(&mut ws, &x, &mut out);
                assert_eq!(out.max_abs_diff(&want), 0.0, "matmat_in diverged");
                ws.give_mat(out);
                let wantv = op.matvec(&xv);
                let mut outv = ws.take_vec(14);
                op.matvec_in(&mut ws, &xv, &mut outv);
                assert_eq!(outv, wantv, "matvec_in diverged");
                ws.give_vec(outv);
            }
        }
        let grows = ws.grows();
        for op in ops {
            let mut out = ws.take_mat(14, 5);
            op.matmat_in(&mut ws, &x, &mut out);
            ws.give_mat(out);
        }
        assert_eq!(ws.grows(), grows, "warmed combinator matmat_in re-allocated");
    }

    #[test]
    fn subtract_lowrank_matches_dense() {
        let mut rng = Pcg64::seeded(7);
        let base = sym(10, 8);
        let w = Matrix::randn(10, 2, &mut rng);
        let op_base = DenseOp::new(base.clone());
        let op = SubtractLowRankOp::new(&op_base, w.clone());
        let dense = &base - &w.matmul(&w.transpose());
        assert!(op.to_dense().max_abs_diff(&dense) < 1e-12);
        let d = op.diagonal();
        for i in 0..10 {
            assert!((d[i] - dense[(i, i)]).abs() < 1e-12);
        }
    }
}

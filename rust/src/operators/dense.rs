//! Dense-matrix operator (testing and small-N baselines).

use super::LinearOp;
use crate::linalg::gemm::NR;
use crate::linalg::{mixed, Matrix, SolveWorkspace};
use std::sync::OnceLock;

/// Wrap an explicit symmetric matrix as a [`LinearOp`].
pub struct DenseOp {
    k: Matrix,
    /// f32 copy of `k`, built once on first mixed MVM (the operator is
    /// immutable after construction, so one downconversion amortizes over
    /// every mixed solve against it).
    k32: OnceLock<Vec<f32>>,
}

impl DenseOp {
    /// Wrap `k` (must be square; symmetry is the caller's contract).
    pub fn new(k: Matrix) -> DenseOp {
        assert_eq!(k.rows(), k.cols(), "DenseOp needs square");
        DenseOp { k, k32: OnceLock::new() }
    }

    /// Access the underlying matrix.
    pub fn matrix(&self) -> &Matrix {
        &self.k
    }
}

impl LinearOp for DenseOp {
    fn size(&self) -> usize {
        self.k.rows()
    }

    fn matvec(&self, x: &[f64]) -> Vec<f64> {
        self.k.matvec(x)
    }

    fn matvec_in(&self, _ws: &mut SolveWorkspace, x: &[f64], out: &mut [f64]) {
        self.k.matvec_into(x, out);
    }

    fn matmat(&self, x: &Matrix) -> Matrix {
        self.k.matmul(x)
    }

    fn matmat_in(&self, _ws: &mut SolveWorkspace, x: &Matrix, out: &mut Matrix) {
        self.k.matmul_into(x, out);
    }

    fn diagonal(&self) -> Vec<f64> {
        (0..self.size()).map(|i| self.k[(i, i)]).collect()
    }

    fn column(&self, j: usize) -> Vec<f64> {
        self.k.col(j)
    }

    fn to_dense(&self) -> Matrix {
        self.k.clone()
    }

    fn supports_mixed(&self) -> bool {
        true
    }

    fn matmat_mixed_in(&self, ws: &mut SolveWorkspace, x: &Matrix, out: &mut Matrix) {
        let n = self.size();
        assert_eq!(x.rows(), n, "matmat_mixed_in x rows mismatch");
        assert_eq!(out.rows(), n, "matmat_mixed_in out rows mismatch");
        assert_eq!(out.cols(), x.cols(), "matmat_mixed_in out cols mismatch");
        let cols = x.cols();
        let k32 = self.k32.get_or_init(|| {
            let mut v = vec![0.0f32; n * n];
            mixed::downconvert(self.k.as_slice(), &mut v);
            v
        });
        let mut b32 = ws.take_f32(n * cols);
        mixed::downconvert(x.as_slice(), &mut b32);
        let mut pack = ws.take_f32(n * NR);
        out.as_mut_slice().fill(0.0);
        mixed::gemm_nn(n, n, cols, k32, &b32, out.as_mut_slice(), &mut pack);
        ws.give_f32(pack);
        ws.give_f32(b32);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn matvec_and_diag() {
        let mut rng = Pcg64::seeded(1);
        let mut a = Matrix::randn(8, 8, &mut rng);
        a.symmetrize();
        let op = DenseOp::new(a.clone());
        let x: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
        let y = op.matvec(&x);
        let y2 = a.matvec(&x);
        assert_eq!(y, y2);
        let d = op.diagonal();
        for i in 0..8 {
            assert_eq!(d[i], a[(i, i)]);
        }
        assert!(op.to_dense().max_abs_diff(&a) < 1e-15);
    }
}

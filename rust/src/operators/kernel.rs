//! Kernel-matrix operators with partitioned, O(N)-memory, threaded MVMs.
//!
//! `K_ij = s² ρ(‖(x_i − x_j)/ℓ‖) + σ² δ_ij` for RBF / Matérn-ν kernels.
//! The MVM streams over row/column tiles: each tile of `K` is computed on
//! the fly from the (lengthscale-scaled) data and immediately contracted
//! against the right-hand sides, mirroring the paper's map-reduce MVMs
//! (refs [11, 79]) and the Pallas kernel's HBM↔VMEM schedule at Layer 1.

use super::LinearOp;
use crate::linalg::Matrix;
use crate::util::threadpool::parallel_fill;

/// Kernel family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelType {
    /// Squared-exponential `exp(-r²/2)`.
    Rbf,
    /// Matérn ν = 1/2: `exp(-r)`.
    Matern12,
    /// Matérn ν = 3/2: `(1+√3 r) exp(-√3 r)`.
    Matern32,
    /// Matérn ν = 5/2: `(1+√5 r+5r²/3) exp(-√5 r)`.
    Matern52,
}

impl KernelType {
    /// Correlation as a function of the scaled distance `r ≥ 0`.
    ///
    /// The MVM hot loop is exp-bound. We benchmarked a bit-twiddled
    /// [`crate::util::fastmath::fast_exp`] here and *reverted* it: this
    /// glibc's `exp` runs at ~6 ns/call and the approximation was 0.9–1.0×
    /// (see EXPERIMENTS.md §Perf, iteration 2).
    #[inline]
    pub fn rho(&self, r: f64) -> f64 {
        match self {
            KernelType::Rbf => (-0.5 * r * r).exp(),
            KernelType::Matern12 => (-r).exp(),
            KernelType::Matern32 => {
                let a = 3f64.sqrt() * r;
                (1.0 + a) * (-a).exp()
            }
            KernelType::Matern52 => {
                let a = 5f64.sqrt() * r;
                (1.0 + a + a * a / 3.0) * (-a).exp()
            }
        }
    }

    /// `d ρ / d log ℓ` as a function of scaled distance `r` (note
    /// `dr/d log ℓ = −r`), used for hyperparameter gradients.
    #[inline]
    pub fn drho_dlog_ell(&self, r: f64) -> f64 {
        match self {
            KernelType::Rbf => r * r * (-0.5 * r * r).exp(),
            KernelType::Matern12 => r * (-r).exp(),
            KernelType::Matern32 => {
                let s = 3f64.sqrt();
                s * r * s * r * (-s * r).exp()
            }
            KernelType::Matern52 => {
                let s = 5f64.sqrt();
                let a = s * r;
                // dρ/dr = -(a/3)(1+a) e^{-a} · s ... computed analytically:
                // ρ(r) = (1+a+a²/3)e^{-a}, dρ/da = (1/3)a(1+a)·(-e^{-a}) + ...
                // dρ/da = -(a + a²)/3 · e^{-a} ... derive: d/da[(1+a+a²/3)e^{-a}]
                //       = (1+2a/3)e^{-a} - (1+a+a²/3)e^{-a} = -(a/3)(1+a)e^{-a}
                // dρ/dlogℓ = dρ/da · da/dlogℓ = -(a/3)(1+a)e^{-a} · (-a)
                a * a / 3.0 * (1.0 + a) * (-a).exp()
            }
        }
    }
}

/// Kernel matrix `K(X, X)` as a [`LinearOp`] with partitioned MVMs.
pub struct KernelOp {
    /// data scaled by 1/lengthscale, row-major `n × d`
    xs: Matrix,
    /// squared norms of scaled rows
    sq: Vec<f64>,
    kind: KernelType,
    outputscale: f64,
    /// diagonal noise σ² (added jitter / observation noise)
    noise: f64,
    /// row-tile size for the partitioned MVM (perf knob)
    tile: usize,
}

impl KernelOp {
    /// Build from raw data `x` (`n × d`), isotropic `lengthscale`,
    /// `outputscale` (= s², the kernel variance), and diagonal `noise` (σ²).
    pub fn new(x: &Matrix, kind: KernelType, lengthscale: f64, outputscale: f64, noise: f64) -> KernelOp {
        let ell = vec![lengthscale; x.cols()];
        Self::new_ard(x, kind, &ell, outputscale, noise)
    }

    /// Build with per-dimension (ARD) lengthscales.
    pub fn new_ard(x: &Matrix, kind: KernelType, lengthscales: &[f64], outputscale: f64, noise: f64) -> KernelOp {
        assert_eq!(lengthscales.len(), x.cols());
        assert!(lengthscales.iter().all(|&l| l > 0.0), "lengthscales must be positive");
        assert!(outputscale > 0.0 && noise >= 0.0);
        let (n, d) = (x.rows(), x.cols());
        let mut xs = Matrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                xs[(i, j)] = x[(i, j)] / lengthscales[j];
            }
        }
        let sq: Vec<f64> = (0..n)
            .map(|i| xs.row(i).iter().map(|v| v * v).sum())
            .collect();
        KernelOp { xs, sq, kind, outputscale, noise, tile: 128 }
    }

    /// Number of data points.
    pub fn n(&self) -> usize {
        self.xs.rows()
    }

    /// Set the row-tile size (performance tuning).
    pub fn with_tile(mut self, tile: usize) -> Self {
        self.tile = tile.max(8);
        self
    }

    /// Kernel value between scaled rows `i` and `j`.
    #[inline]
    fn kval(&self, i: usize, j: usize) -> f64 {
        let d2 = (self.sq[i] + self.sq[j]
            - 2.0 * dot(self.xs.row(i), self.xs.row(j)))
        .max(0.0);
        let base = self.outputscale * self.kind.rho(d2.sqrt());
        if i == j {
            base + self.noise
        } else {
            base
        }
    }

    /// Fused gradient contraction `Σ_ij l_i (∂K_ij/∂θ) r_j` for
    /// `θ ∈ {log ℓ, log s²}`, computed in one tiled O(N² d) pass.
    /// Returns `(d_log_ell, d_log_s2)`. The noise term is excluded
    /// (its gradient is `Σ_i l_i r_i · σ²` for log-noise, handled by callers).
    pub fn grad_contract(&self, l: &[f64], r: &[f64]) -> (f64, f64) {
        let n = self.n();
        assert_eq!(l.len(), n);
        assert_eq!(r.len(), n);
        let mut d_ell = 0.0;
        let mut d_s2 = 0.0;
        for i in 0..n {
            let xi = self.xs.row(i);
            let li = l[i];
            if li == 0.0 {
                continue;
            }
            for j in 0..n {
                let d2 = (self.sq[i] + self.sq[j] - 2.0 * dot(xi, self.xs.row(j))).max(0.0);
                let rr = d2.sqrt();
                d_ell += li * r[j] * self.outputscale * self.kind.drho_dlog_ell(rr);
                d_s2 += li * r[j] * self.outputscale * self.kind.rho(rr);
            }
        }
        (d_ell, d_s2)
    }
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    let mut s = 0.0;
    for (x, y) in a.iter().zip(b) {
        s += x * y;
    }
    s
}

impl LinearOp for KernelOp {
    fn size(&self) -> usize {
        self.n()
    }

    fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let m = Matrix::from_vec(x.len(), 1, x.to_vec());
        let out = self.matmat(&m);
        out.as_slice().to_vec()
    }

    fn matmat(&self, b: &Matrix) -> Matrix {
        let n = self.n();
        assert_eq!(b.rows(), n, "kernel matmat dim mismatch");
        let r = b.cols();
        let mut out = Matrix::zeros(n, r);
        let tile = self.tile;
        let flat = out.as_mut_slice();
        // one block = `tile` output rows; blocks are written disjointly
        parallel_fill(flat, tile * r.max(1), |start_flat, block| {
            let i0 = start_flat / r.max(1);
            let rows = block.len() / r.max(1);
            for jt in (0..n).step_by(tile) {
                let j1 = (jt + tile).min(n);
                for bi in 0..rows {
                    let i = i0 + bi;
                    let xi = self.xs.row(i);
                    let orow = &mut block[bi * r..(bi + 1) * r];
                    for j in jt..j1 {
                        let d2 = (self.sq[i] + self.sq[j] - 2.0 * dot(xi, self.xs.row(j))).max(0.0);
                        let mut k = self.outputscale * self.kind.rho(d2.sqrt());
                        if i == j {
                            k += self.noise;
                        }
                        let brow = b.row(j);
                        for (o, bv) in orow.iter_mut().zip(brow) {
                            *o += k * bv;
                        }
                    }
                }
            }
        });
        out
    }

    fn diagonal(&self) -> Vec<f64> {
        vec![self.outputscale * self.kind.rho(0.0) + self.noise; self.n()]
    }

    fn column(&self, j: usize) -> Vec<f64> {
        (0..self.n()).map(|i| self.kval(i, j)).collect()
    }

    fn lambda_min_bound(&self) -> Option<f64> {
        // K = s²·C + σ²I with C PSD ⇒ λ_min ≥ σ².
        if self.noise > 0.0 {
            Some(self.noise)
        } else {
            None
        }
    }
}

/// Cross-kernel matrix `K(X1, X2)` (`n1 × n2`), same scaling conventions as
/// [`KernelOp`] (no noise term — it is not square in general).
pub fn cross_kernel(
    x1: &Matrix,
    x2: &Matrix,
    kind: KernelType,
    lengthscales: &[f64],
    outputscale: f64,
) -> Matrix {
    assert_eq!(x1.cols(), x2.cols());
    assert_eq!(lengthscales.len(), x1.cols());
    let (n1, n2, d) = (x1.rows(), x2.rows(), x1.cols());
    let scale = |x: &Matrix| {
        let mut s = Matrix::zeros(x.rows(), d);
        for i in 0..x.rows() {
            for j in 0..d {
                s[(i, j)] = x[(i, j)] / lengthscales[j];
            }
        }
        s
    };
    let (s1, s2) = (scale(x1), scale(x2));
    let q1: Vec<f64> = (0..n1).map(|i| s1.row(i).iter().map(|v| v * v).sum()).collect();
    let q2: Vec<f64> = (0..n2).map(|i| s2.row(i).iter().map(|v| v * v).sum()).collect();
    let mut out = Matrix::zeros(n1, n2);
    for i in 0..n1 {
        let row = s1.row(i);
        for j in 0..n2 {
            let d2 = (q1[i] + q2[j] - 2.0 * dot(row, s2.row(j))).max(0.0);
            out[(i, j)] = outputscale * kind.rho(d2.sqrt());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn data(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seeded(seed);
        Matrix::randn(n, d, &mut rng)
    }

    #[test]
    fn matvec_matches_dense() {
        let x = data(60, 3, 1);
        let mut rng = Pcg64::seeded(2);
        for kind in [KernelType::Rbf, KernelType::Matern12, KernelType::Matern32, KernelType::Matern52] {
            let op = KernelOp::new(&x, kind, 0.7, 1.3, 0.01).with_tile(16);
            let dense = op.to_dense();
            let v: Vec<f64> = (0..60).map(|_| rng.normal()).collect();
            let y1 = op.matvec(&v);
            let y2 = dense.matvec(&v);
            for (a, b) in y1.iter().zip(&y2) {
                assert!((a - b).abs() < 1e-10, "{kind:?}");
            }
        }
    }

    #[test]
    fn dense_is_symmetric_psd_diag() {
        let x = data(40, 2, 3);
        let op = KernelOp::new(&x, KernelType::Rbf, 1.0, 2.0, 0.1);
        let k = op.to_dense();
        for i in 0..40 {
            assert!((k[(i, i)] - 2.1).abs() < 1e-12);
            for j in 0..40 {
                assert!((k[(i, j)] - k[(j, i)]).abs() < 1e-12);
                assert!(k[(i, j)] <= 2.1 + 1e-12);
            }
        }
        // PSD: Cholesky with tiny jitter succeeds
        assert!(crate::linalg::Cholesky::with_jitter(&k, 1e-10).is_ok());
    }

    #[test]
    fn matmat_matches_matvec_columns() {
        let x = data(30, 4, 4);
        let op = KernelOp::new(&x, KernelType::Matern52, 0.5, 1.0, 0.0).with_tile(8);
        let mut rng = Pcg64::seeded(5);
        let b = Matrix::randn(30, 5, &mut rng);
        let y = op.matmat(&b);
        for j in 0..5 {
            let yj = op.matvec(&b.col(j));
            for i in 0..30 {
                assert!((y[(i, j)] - yj[i]).abs() < 1e-11);
            }
        }
    }

    #[test]
    fn ard_scaling_consistent() {
        let x = data(20, 2, 6);
        // ARD with equal lengthscales == isotropic
        let a = KernelOp::new_ard(&x, KernelType::Rbf, &[0.5, 0.5], 1.0, 0.0);
        let b = KernelOp::new(&x, KernelType::Rbf, 0.5, 1.0, 0.0);
        assert!(a.to_dense().max_abs_diff(&b.to_dense()) < 1e-12);
    }

    #[test]
    fn cross_kernel_matches_square() {
        let x = data(15, 3, 7);
        let op = KernelOp::new(&x, KernelType::Matern32, 0.8, 1.5, 0.0);
        let cross = cross_kernel(&x, &x, KernelType::Matern32, &[0.8, 0.8, 0.8], 1.5);
        assert!(cross.max_abs_diff(&op.to_dense()) < 1e-12);
    }

    #[test]
    fn grad_contract_matches_finite_difference() {
        let x = data(12, 2, 8);
        let mut rng = Pcg64::seeded(9);
        let l: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let r: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        for kind in [KernelType::Rbf, KernelType::Matern12, KernelType::Matern32, KernelType::Matern52] {
            let (ell, s2) = (0.8, 1.4);
            let op = KernelOp::new(&x, kind, ell, s2, 0.0);
            let (g_ell, g_s2) = op.grad_contract(&l, &r);
            let f = |ell: f64, s2: f64| -> f64 {
                let o = KernelOp::new(&x, kind, ell, s2, 0.0);
                crate::util::dot(&l, &o.matvec(&r))
            };
            let h: f64 = 1e-5;
            // d/d log ell
            let fd_ell = (f(ell * h.exp(), s2) - f(ell * (-h).exp(), s2)) / (2.0 * h);
            let fd_s2 = (f(ell, s2 * h.exp()) - f(ell, s2 * (-h).exp())) / (2.0 * h);
            assert!(
                (g_ell - fd_ell).abs() < 1e-4 * (1.0 + fd_ell.abs()),
                "{kind:?} ell grad {g_ell} vs fd {fd_ell}"
            );
            assert!(
                (g_s2 - fd_s2).abs() < 1e-4 * (1.0 + fd_s2.abs()),
                "{kind:?} s2 grad {g_s2} vs fd {fd_s2}"
            );
        }
    }
}

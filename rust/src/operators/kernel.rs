//! Kernel-matrix operators with partitioned, O(N)-memory, threaded MVMs.
//!
//! `K_ij = s² ρ(‖(x_i − x_j)/ℓ‖) + σ² δ_ij` for RBF / Matérn-ν kernels.
//! The MVM streams over row/column tiles, mirroring the paper's map-reduce
//! MVMs (refs [11, 79]) and the Pallas kernel's HBM↔VMEM schedule at
//! Layer 1. Each `(i-block, j-tile)` step is a three-stage **panel
//! pipeline** rather than a per-entry scalar loop:
//!
//! 1. the squared-distance tile `d²_ij = ‖x_i‖² + ‖x_j‖² − 2·x_i·x_jᵀ`
//!    materializes as one Gram panel via the register-blocked
//!    [`gemm::gemm_nt`] micro-kernel,
//! 2. `ρ` (or `dρ`) is applied over the contiguous panel in place —
//!    lane-parallel through [`crate::linalg::simd`]'s vector `exp` when a
//!    SIMD backend is active, per-entry glibc `exp` otherwise,
//! 3. the panel contracts against the right-hand-side block with a second
//!    small GEMM ([`gemm::gemm_nn`]).
//!
//! Blocks run on the persistent thread pool; [`KernelOp::matmat_naive`] and
//! [`KernelOp::grad_contract_naive`] keep the pre-panel per-entry engine as
//! the before-side of `BENCH_kernel_mvm.json` and as the oracle for the
//! panel pipeline's property tests.

use super::LinearOp;
use crate::linalg::simd::{self, RhoFamily};
use crate::linalg::{gemm, mixed, Matrix, SolveWorkspace};
use crate::util::threadpool::{num_threads, parallel_fill_scoped, parallel_fill_threads, parallel_map_threads};
use std::cell::RefCell;
use std::sync::OnceLock;

std::thread_local! {
    // Per-thread (Gram panel, GEMM pack) scratch for the panel pipeline:
    // sized on first use per thread, then every later MVM on that thread is
    // allocation-free — the kernel-operator half of the solve stack's
    // zero-allocation steady state.
    static PANEL_SCRATCH: RefCell<(Vec<f64>, Vec<f64>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };

    // Mixed-precision twin of `PANEL_SCRATCH`: f32 (Gram panel, GEMM pack)
    // scratch for the f32-storage pipeline (`rust/DESIGN.md` §9). Kept
    // separate so flipping a request's precision policy never evicts the
    // other tier's warmed buffers.
    static PANEL_SCRATCH_F32: RefCell<(Vec<f32>, Vec<f32>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
}

/// Kernel family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelType {
    /// Squared-exponential `exp(-r²/2)`.
    Rbf,
    /// Matérn ν = 1/2: `exp(-r)`.
    Matern12,
    /// Matérn ν = 3/2: `(1+√3 r) exp(-√3 r)`.
    Matern32,
    /// Matérn ν = 5/2: `(1+√5 r+5r²/3) exp(-√5 r)`.
    Matern52,
}

impl KernelType {
    /// The SIMD-facing correlation family this kernel evaluates —
    /// [`RhoFamily`] owns the `ρ`/`dρ` formulas (scalar *and* vector) so the
    /// panel pipeline, the lane remainders, and these scalar accessors all
    /// share one implementation.
    #[inline]
    pub fn family(&self) -> RhoFamily {
        match self {
            KernelType::Rbf => RhoFamily::Rbf,
            KernelType::Matern12 => RhoFamily::Matern12,
            KernelType::Matern32 => RhoFamily::Matern32,
            KernelType::Matern52 => RhoFamily::Matern52,
        }
    }

    /// Correlation as a function of the scaled distance `r ≥ 0` (glibc
    /// `exp` path).
    ///
    /// The MVM hot loop is exp-bound. We benchmarked a bit-twiddled scalar
    /// [`crate::util::fastmath::fast_exp`] here and *reverted* it: this
    /// glibc's `exp` runs at ~6 ns/call and the approximation was 0.9–1.0×
    /// (see EXPERIMENTS.md §Perf, iteration 2). The *vector* `exp` inside
    /// [`crate::linalg::simd`] is different economics — it amortizes the
    /// range reduction over 4–8 lanes — and is what the panel pipeline uses
    /// when a SIMD backend is active.
    #[inline]
    pub fn rho(&self, r: f64) -> f64 {
        self.family().rho(r)
    }

    /// `d ρ / d log ℓ` as a function of scaled distance `r` (note
    /// `dr/d log ℓ = −r`), used for hyperparameter gradients.
    #[inline]
    pub fn drho_dlog_ell(&self, r: f64) -> f64 {
        self.family().drho_dlog_ell(r)
    }
}

/// Kernel matrix `K(X, X)` as a [`LinearOp`] with partitioned MVMs.
pub struct KernelOp {
    /// data scaled by 1/lengthscale, row-major `n × d`
    xs: Matrix,
    /// squared norms of scaled rows
    sq: Vec<f64>,
    kind: KernelType,
    outputscale: f64,
    /// diagonal noise σ² (added jitter / observation noise)
    noise: f64,
    /// row-tile size for the partitioned MVM (perf knob)
    tile: usize,
    /// thread-count override for this operator's panel pipeline
    /// (`None` = global [`num_threads`]; `Some(1)` = fully serial)
    threads: Option<usize>,
    /// f32 copies of (`xs`, `sq`), built once on first mixed MVM — the
    /// operator is immutable after construction, so the downconversion
    /// amortizes across every mixed solve on this operator version.
    mixed: OnceLock<(Vec<f32>, Vec<f32>)>,
}

impl KernelOp {
    /// Build from raw data `x` (`n × d`), isotropic `lengthscale`,
    /// `outputscale` (= s², the kernel variance), and diagonal `noise` (σ²).
    pub fn new(x: &Matrix, kind: KernelType, lengthscale: f64, outputscale: f64, noise: f64) -> KernelOp {
        let ell = vec![lengthscale; x.cols()];
        Self::new_ard(x, kind, &ell, outputscale, noise)
    }

    /// Build with per-dimension (ARD) lengthscales.
    pub fn new_ard(x: &Matrix, kind: KernelType, lengthscales: &[f64], outputscale: f64, noise: f64) -> KernelOp {
        assert_eq!(lengthscales.len(), x.cols());
        assert!(lengthscales.iter().all(|&l| l > 0.0), "lengthscales must be positive");
        assert!(outputscale > 0.0 && noise >= 0.0);
        let (n, d) = (x.rows(), x.cols());
        let mut xs = Matrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                xs[(i, j)] = x[(i, j)] / lengthscales[j];
            }
        }
        let sq: Vec<f64> = (0..n)
            .map(|i| xs.row(i).iter().map(|v| v * v).sum())
            .collect();
        let mixed = OnceLock::new();
        KernelOp { xs, sq, kind, outputscale, noise, tile: 128, threads: None, mixed }
    }

    /// Number of data points.
    pub fn n(&self) -> usize {
        self.xs.rows()
    }

    /// Set the row-tile size (performance tuning).
    pub fn with_tile(mut self, tile: usize) -> Self {
        self.tile = tile.max(8);
        self
    }

    /// Override the thread count for this operator's MVM/gradient pipeline
    /// (default: the global [`num_threads`], i.e. `CIQ_THREADS`). `1` forces
    /// the fully serial path — used by the property tests to cover
    /// `CIQ_THREADS ∈ {1, many}` inside one process.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads.max(1));
        self
    }

    /// The panel-pipeline engine behind [`LinearOp::matmat`] /
    /// [`LinearOp::matmat_in`]: computes `K·B` into the row-major `flat`
    /// output slice. Gram-panel and GEMM-pack scratch are reused
    /// thread-locals, so a warm call performs zero heap allocations on every
    /// participating thread.
    fn matmat_into_slice(&self, b: &Matrix, flat: &mut [f64]) {
        let n = self.n();
        assert_eq!(b.rows(), n, "kernel matmat dim mismatch");
        let r = b.cols();
        assert_eq!(flat.len(), n * r, "kernel matmat out size mismatch");
        flat.fill(0.0);
        if n == 0 || r == 0 {
            return;
        }
        let tile = self.tile;
        let d = self.xs.cols();
        let xs = self.xs.as_slice();
        let nthreads = self.threads.unwrap_or_else(num_threads);
        // resolve SIMD dispatch once per matmat, outside the parallel
        // closure (a `&'static` table is freely shared across workers)
        let tbl = simd::table();
        let fam = self.kind.family();
        // one block = `tile` output rows; blocks are written disjointly
        parallel_fill_threads(flat, tile * r, nthreads, |start_flat, block| {
            let i0 = start_flat / r;
            let rows = block.len() / r;
            PANEL_SCRATCH.with(|scratch| {
                let (panel, pack) = &mut *scratch.borrow_mut();
                if panel.len() < rows * tile {
                    panel.resize(rows * tile, 0.0);
                }
                for jt in (0..n).step_by(tile) {
                    let j1 = (jt + tile).min(n);
                    let jw = j1 - jt;
                    let pan = &mut panel[..rows * jw];
                    pan.fill(0.0);
                    // stage 1: pan = X(i-block) · X(j-tile)ᵀ (micro-kernel GEMM)
                    gemm::gemm_nt(rows, d, jw, &xs[i0 * d..(i0 + rows) * d], &xs[jt * d..j1 * d], pan);
                    // stage 2: pan ← s²·ρ(√max(‖xi‖²+‖xj‖²−2·pan, 0)) (+σ² diag)
                    for bi in 0..rows {
                        let i = i0 + bi;
                        let sqi = self.sq[i];
                        let prow = &mut pan[bi * jw..(bi + 1) * jw];
                        if let Some(t) = tbl {
                            // lane-parallel ρ over the contiguous panel row
                            (t.rho_row)(fam, self.outputscale, sqi, &self.sq[jt..j1], prow);
                        } else {
                            for (jj, v) in prow.iter_mut().enumerate() {
                                let d2 = (sqi + self.sq[jt + jj] - 2.0 * *v).max(0.0);
                                *v = self.outputscale * self.kind.rho(d2.sqrt());
                            }
                        }
                        if i >= jt && i < j1 {
                            prow[i - jt] += self.noise;
                        }
                    }
                    // stage 3: out-block += pan · B(j-tile) (second small GEMM)
                    gemm::gemm_nn_with_pack(rows, jw, r, pan, &b.as_slice()[jt * r..j1 * r], block, pack);
                }
            });
        });
    }

    /// The f32 copies of (`xs`, `sq`), downconverted once per operator
    /// (and thus once per operator *version* — `replace_operator` builds a
    /// fresh `KernelOp`).
    fn mixed_data(&self) -> (&[f32], &[f32]) {
        let (xs32, sq32) = self.mixed.get_or_init(|| {
            let mut xs32 = vec![0.0f32; self.xs.as_slice().len()];
            mixed::downconvert(self.xs.as_slice(), &mut xs32);
            let mut sq32 = vec![0.0f32; self.sq.len()];
            mixed::downconvert(&self.sq, &mut sq32);
            (xs32, sq32)
        });
        (xs32, sq32)
    }

    /// Mixed-precision twin of [`Self::matmat_into_slice`]: the same
    /// three-stage panel pipeline with f32 storage and f64 accumulation
    /// (`rust/DESIGN.md` §9). `B` is downconverted once per call into a
    /// pooled workspace f32 slab; panels/packs come from
    /// `PANEL_SCRATCH_F32`, so a warm call performs zero heap allocations.
    /// Forward error is O(f32 ε) per entry — callers restore f64-grade
    /// residuals through the iterative-refinement loop upstairs.
    fn matmat_mixed_into_slice(&self, ws: &mut SolveWorkspace, b: &Matrix, flat: &mut [f64]) {
        let n = self.n();
        assert_eq!(b.rows(), n, "kernel mixed matmat dim mismatch");
        let r = b.cols();
        assert_eq!(flat.len(), n * r, "kernel mixed matmat out size mismatch");
        flat.fill(0.0);
        if n == 0 || r == 0 {
            return;
        }
        let tile = self.tile;
        let d = self.xs.cols();
        let (xs32, sq32) = self.mixed_data();
        let mut b32 = ws.take_f32(n * r);
        mixed::downconvert(b.as_slice(), &mut b32);
        let nthreads = self.threads.unwrap_or_else(num_threads);
        // resolve mixed SIMD dispatch once per matmat, outside the parallel
        // closure (a `&'static` table is freely shared across workers)
        let tbl = mixed::table();
        let fam = self.kind.family();
        // precision: σ² jitter narrowed once per matmat; |σ²| ≤ kernel scale,
        // so the rounding is within the f32 panel's own O(ε₃₂) forward error.
        let noise32 = self.noise as f32;
        let b32_ref: &[f32] = &b32;
        parallel_fill_threads(flat, tile * r, nthreads, |start_flat, block| {
            let i0 = start_flat / r;
            let rows = block.len() / r;
            PANEL_SCRATCH_F32.with(|scratch| {
                let (panel, pack) = &mut *scratch.borrow_mut();
                if panel.len() < rows * tile {
                    panel.resize(rows * tile, 0.0);
                }
                for jt in (0..n).step_by(tile) {
                    let j1 = (jt + tile).min(n);
                    let jw = j1 - jt;
                    let pan = &mut panel[..rows * jw];
                    pan.fill(0.0);
                    // stage 1: pan = X₃₂(i-block) · X₃₂(j-tile)ᵀ (f64 dots,
                    // one f32 rounding per Gram entry)
                    mixed::gemm_nt(rows, d, jw, &xs32[i0 * d..(i0 + rows) * d], &xs32[jt * d..j1 * d], pan);
                    // stage 2: pan ← s²·ρ(√max(‖xi‖²+‖xj‖²−2·pan, 0)) (+σ² diag)
                    for bi in 0..rows {
                        let i = i0 + bi;
                        let sqi = sq32[i];
                        let prow = &mut pan[bi * jw..(bi + 1) * jw];
                        if let Some(t) = tbl {
                            // lane-parallel ρ over the contiguous f32 panel row
                            (t.rho_row)(fam, self.outputscale, sqi, &sq32[jt..j1], prow);
                        } else {
                            mixed::rho_row_scalar(fam, self.outputscale, sqi, &sq32[jt..j1], prow);
                        }
                        if i >= jt && i < j1 {
                            prow[i - jt] += noise32;
                        }
                    }
                    // stage 3: out-block += pan · B₃₂(j-tile) into f64
                    mixed::gemm_nn(rows, jw, r, pan, &b32_ref[jt * r..j1 * r], block, pack);
                }
            });
        });
        ws.give_f32(b32);
    }

    /// Mixed-precision twin of [`Self::grad_contract`]: f32 panels and
    /// distances, f64 contraction sums. The residual column `r` stays f64 —
    /// gradients feed optimizer steps directly, so the reduction keeps full
    /// precision even when the panel does not.
    pub fn grad_contract_mixed(&self, l: &[f64], r: &[f64]) -> (f64, f64) {
        let n = self.n();
        assert_eq!(l.len(), n);
        assert_eq!(r.len(), n);
        if n == 0 {
            return (0.0, 0.0);
        }
        let tile = self.tile;
        let d = self.xs.cols();
        let (xs32, sq32) = self.mixed_data();
        let ntiles = n.div_ceil(tile);
        let nthreads = self.threads.unwrap_or_else(num_threads);
        let tbl = mixed::table();
        let fam = self.kind.family();
        let partials: Vec<(f64, f64)> = parallel_map_threads(ntiles, nthreads, |ti| {
            let it0 = ti * tile;
            let it1 = (it0 + tile).min(n);
            let rows = it1 - it0;
            let mut panel = vec![0.0f32; rows * tile];
            let mut d_ell = 0.0;
            let mut d_s2 = 0.0;
            for jt in (0..n).step_by(tile) {
                let j1 = (jt + tile).min(n);
                let jw = j1 - jt;
                let pan = &mut panel[..rows * jw];
                pan.fill(0.0);
                mixed::gemm_nt(rows, d, jw, &xs32[it0 * d..it1 * d], &xs32[jt * d..j1 * d], pan);
                for bi in 0..rows {
                    let i = it0 + bi;
                    let li = l[i];
                    if li == 0.0 {
                        continue;
                    }
                    let sqi = sq32[i];
                    let prow = &pan[bi * jw..(bi + 1) * jw];
                    let (de, ds) = if let Some(t) = tbl {
                        // lane-parallel dρ/ρ contraction over the f32 panel row
                        (t.grad_row)(fam, self.outputscale, li, sqi, &sq32[jt..j1], prow, &r[jt..j1])
                    } else {
                        mixed::grad_row_scalar(
                            fam,
                            self.outputscale,
                            li,
                            sqi,
                            &sq32[jt..j1],
                            prow,
                            &r[jt..j1],
                        )
                    };
                    d_ell += de;
                    d_s2 += ds;
                }
            }
            (d_ell, d_s2)
        });
        partials.into_iter().fold((0.0, 0.0), |(a, b), (x, y)| (a + x, b + y))
    }

    /// Kernel value between scaled rows `i` and `j`.
    #[inline]
    fn kval(&self, i: usize, j: usize) -> f64 {
        let d2 = (self.sq[i] + self.sq[j]
            - 2.0 * dot(self.xs.row(i), self.xs.row(j)))
        .max(0.0);
        let base = self.outputscale * self.kind.rho(d2.sqrt());
        if i == j {
            base + self.noise
        } else {
            base
        }
    }

    /// Fused gradient contraction `Σ_ij l_i (∂K_ij/∂θ) r_j` for
    /// `θ ∈ {log ℓ, log s²}`, computed in one tiled O(N² d) pass.
    /// Returns `(d_log_ell, d_log_s2)`. The noise term is excluded
    /// (its gradient is `Σ_i l_i r_i · σ²` for log-noise, handled by callers).
    ///
    /// Like [`LinearOp::matmat`], each distance tile materializes as a Gram
    /// panel through the micro-kernel, `ρ`/`dρ` run over the contiguous
    /// panel, and row tiles are distributed over the thread pool with the
    /// per-tile partial sums reduced at the end.
    pub fn grad_contract(&self, l: &[f64], r: &[f64]) -> (f64, f64) {
        let n = self.n();
        assert_eq!(l.len(), n);
        assert_eq!(r.len(), n);
        if n == 0 {
            return (0.0, 0.0);
        }
        let tile = self.tile;
        let d = self.xs.cols();
        let xs = self.xs.as_slice();
        let ntiles = n.div_ceil(tile);
        let nthreads = self.threads.unwrap_or_else(num_threads);
        let tbl = simd::table();
        let fam = self.kind.family();
        let partials: Vec<(f64, f64)> = parallel_map_threads(ntiles, nthreads, |ti| {
            let it0 = ti * tile;
            let it1 = (it0 + tile).min(n);
            let rows = it1 - it0;
            let mut panel = vec![0.0f64; rows * tile];
            let mut d_ell = 0.0;
            let mut d_s2 = 0.0;
            for jt in (0..n).step_by(tile) {
                let j1 = (jt + tile).min(n);
                let jw = j1 - jt;
                let pan = &mut panel[..rows * jw];
                pan.fill(0.0);
                gemm::gemm_nt(rows, d, jw, &xs[it0 * d..it1 * d], &xs[jt * d..j1 * d], pan);
                for bi in 0..rows {
                    let i = it0 + bi;
                    let li = l[i];
                    if li == 0.0 {
                        continue;
                    }
                    let sqi = self.sq[i];
                    let prow = &pan[bi * jw..(bi + 1) * jw];
                    if let Some(t) = tbl {
                        // lane-parallel dρ/ρ contraction over the panel row
                        let (de, ds) = (t.grad_row)(
                            fam,
                            self.outputscale,
                            li,
                            sqi,
                            &self.sq[jt..j1],
                            prow,
                            &r[jt..j1],
                        );
                        d_ell += de;
                        d_s2 += ds;
                    } else {
                        for (jj, &xx) in prow.iter().enumerate() {
                            let j = jt + jj;
                            let rr = (sqi + self.sq[j] - 2.0 * xx).max(0.0).sqrt();
                            let lr = li * r[j] * self.outputscale;
                            d_ell += lr * self.kind.drho_dlog_ell(rr);
                            d_s2 += lr * self.kind.rho(rr);
                        }
                    }
                }
            }
            (d_ell, d_s2)
        });
        partials.into_iter().fold((0.0, 0.0), |(a, b), (x, y)| (a + x, b + y))
    }

    /// Pre-panel reference for [`Self::grad_contract`]: per-entry scalar
    /// distances, serial. Oracle for the panel property tests.
    pub fn grad_contract_naive(&self, l: &[f64], r: &[f64]) -> (f64, f64) {
        let n = self.n();
        assert_eq!(l.len(), n);
        assert_eq!(r.len(), n);
        let mut d_ell = 0.0;
        let mut d_s2 = 0.0;
        for i in 0..n {
            let xi = self.xs.row(i);
            let li = l[i];
            if li == 0.0 {
                continue;
            }
            for j in 0..n {
                let d2 = (self.sq[i] + self.sq[j] - 2.0 * dot(xi, self.xs.row(j))).max(0.0);
                let rr = d2.sqrt();
                d_ell += li * r[j] * self.outputscale * self.kind.drho_dlog_ell(rr);
                d_s2 += li * r[j] * self.outputscale * self.kind.rho(rr);
            }
        }
        (d_ell, d_s2)
    }

    /// Pre-panel reference engine for [`LinearOp::matmat`]: per-entry scalar
    /// `dot` distances and spawn-per-call threading
    /// ([`parallel_fill_scoped`]). Kept as the *before* side of the
    /// `BENCH_kernel_mvm.json` comparison and as a correctness oracle.
    pub fn matmat_naive(&self, b: &Matrix) -> Matrix {
        let n = self.n();
        assert_eq!(b.rows(), n, "kernel matmat dim mismatch");
        let r = b.cols();
        let mut out = Matrix::zeros(n, r);
        let tile = self.tile;
        let flat = out.as_mut_slice();
        parallel_fill_scoped(flat, tile * r.max(1), |start_flat, block| {
            let i0 = start_flat / r.max(1);
            let rows = block.len() / r.max(1);
            for jt in (0..n).step_by(tile) {
                let j1 = (jt + tile).min(n);
                for bi in 0..rows {
                    let i = i0 + bi;
                    let xi = self.xs.row(i);
                    let orow = &mut block[bi * r..(bi + 1) * r];
                    for j in jt..j1 {
                        let d2 = (self.sq[i] + self.sq[j] - 2.0 * dot(xi, self.xs.row(j))).max(0.0);
                        let mut k = self.outputscale * self.kind.rho(d2.sqrt());
                        if i == j {
                            k += self.noise;
                        }
                        let brow = b.row(j);
                        for (o, bv) in orow.iter_mut().zip(brow) {
                            *o += k * bv;
                        }
                    }
                }
            }
        });
        out
    }
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    gemm::dot_unrolled(a, b)
}

impl LinearOp for KernelOp {
    fn size(&self) -> usize {
        self.n()
    }

    fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let m = Matrix::from_vec(x.len(), 1, x.to_vec());
        let out = self.matmat(&m);
        out.as_slice().to_vec()
    }

    fn matvec_in(&self, ws: &mut SolveWorkspace, x: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), self.n(), "kernel matvec_in out dim mismatch");
        let mut xm = ws.take_mat(x.len(), 1);
        xm.as_mut_slice().copy_from_slice(x);
        self.matmat_into_slice(&xm, out);
        ws.give_mat(xm);
    }

    fn matmat(&self, b: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.n(), b.cols());
        self.matmat_into_slice(b, out.as_mut_slice());
        out
    }

    fn matmat_in(&self, _ws: &mut SolveWorkspace, b: &Matrix, out: &mut Matrix) {
        assert_eq!(out.rows(), self.n(), "kernel matmat_in out rows mismatch");
        assert_eq!(out.cols(), b.cols(), "kernel matmat_in out cols mismatch");
        self.matmat_into_slice(b, out.as_mut_slice());
    }

    fn diagonal(&self) -> Vec<f64> {
        vec![self.outputscale * self.kind.rho(0.0) + self.noise; self.n()]
    }

    fn column(&self, j: usize) -> Vec<f64> {
        (0..self.n()).map(|i| self.kval(i, j)).collect()
    }

    fn lambda_min_bound(&self) -> Option<f64> {
        // K = s²·C + σ²I with C PSD ⇒ λ_min ≥ σ².
        if self.noise > 0.0 {
            Some(self.noise)
        } else {
            None
        }
    }

    fn supports_mixed(&self) -> bool {
        true
    }

    fn matmat_mixed_in(&self, ws: &mut SolveWorkspace, b: &Matrix, out: &mut Matrix) {
        assert_eq!(out.rows(), self.n(), "kernel matmat_mixed_in out rows mismatch");
        assert_eq!(out.cols(), b.cols(), "kernel matmat_mixed_in out cols mismatch");
        // `out`'s flat storage and `ws` are disjoint borrows; the pipeline
        // only draws its B₃₂ slab from `ws`.
        let n = self.n();
        let r = b.cols();
        let flat = out.as_mut_slice();
        debug_assert_eq!(flat.len(), n * r);
        self.matmat_mixed_into_slice(ws, b, flat);
    }
}

/// Cross-kernel matrix `K(X1, X2)` (`n1 × n2`), same scaling conventions as
/// [`KernelOp`] (no noise term — it is not square in general).
pub fn cross_kernel(
    x1: &Matrix,
    x2: &Matrix,
    kind: KernelType,
    lengthscales: &[f64],
    outputscale: f64,
) -> Matrix {
    assert_eq!(x1.cols(), x2.cols());
    assert_eq!(lengthscales.len(), x1.cols());
    let (n1, n2, d) = (x1.rows(), x2.rows(), x1.cols());
    let scale = |x: &Matrix| {
        let mut s = Matrix::zeros(x.rows(), d);
        for i in 0..x.rows() {
            for j in 0..d {
                s[(i, j)] = x[(i, j)] / lengthscales[j];
            }
        }
        s
    };
    let (s1, s2) = (scale(x1), scale(x2));
    let q1: Vec<f64> = (0..n1).map(|i| s1.row(i).iter().map(|v| v * v).sum()).collect();
    let q2: Vec<f64> = (0..n2).map(|i| s2.row(i).iter().map(|v| v * v).sum()).collect();
    let mut out = Matrix::zeros(n1, n2);
    for i in 0..n1 {
        let row = s1.row(i);
        for j in 0..n2 {
            let d2 = (q1[i] + q2[j] - 2.0 * dot(row, s2.row(j))).max(0.0);
            out[(i, j)] = outputscale * kind.rho(d2.sqrt());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn data(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seeded(seed);
        Matrix::randn(n, d, &mut rng)
    }

    #[test]
    fn matvec_matches_dense() {
        let x = data(60, 3, 1);
        let mut rng = Pcg64::seeded(2);
        for kind in [KernelType::Rbf, KernelType::Matern12, KernelType::Matern32, KernelType::Matern52] {
            let op = KernelOp::new(&x, kind, 0.7, 1.3, 0.01).with_tile(16);
            let dense = op.to_dense();
            let v: Vec<f64> = (0..60).map(|_| rng.normal()).collect();
            let y1 = op.matvec(&v);
            let y2 = dense.matvec(&v);
            for (a, b) in y1.iter().zip(&y2) {
                assert!((a - b).abs() < 1e-10, "{kind:?}");
            }
        }
    }

    #[test]
    fn dense_is_symmetric_psd_diag() {
        let x = data(40, 2, 3);
        let op = KernelOp::new(&x, KernelType::Rbf, 1.0, 2.0, 0.1);
        let k = op.to_dense();
        for i in 0..40 {
            assert!((k[(i, i)] - 2.1).abs() < 1e-12);
            for j in 0..40 {
                assert!((k[(i, j)] - k[(j, i)]).abs() < 1e-12);
                assert!(k[(i, j)] <= 2.1 + 1e-12);
            }
        }
        // PSD: Cholesky with tiny jitter succeeds
        assert!(crate::linalg::Cholesky::with_jitter(&k, 1e-10).is_ok());
    }

    #[test]
    fn matmat_matches_matvec_columns() {
        let x = data(30, 4, 4);
        let op = KernelOp::new(&x, KernelType::Matern52, 0.5, 1.0, 0.0).with_tile(8);
        let mut rng = Pcg64::seeded(5);
        let b = Matrix::randn(30, 5, &mut rng);
        let y = op.matmat(&b);
        for j in 0..5 {
            let yj = op.matvec(&b.col(j));
            for i in 0..30 {
                assert!((y[(i, j)] - yj[i]).abs() < 1e-11);
            }
        }
    }

    #[test]
    fn ard_scaling_consistent() {
        let x = data(20, 2, 6);
        // ARD with equal lengthscales == isotropic
        let a = KernelOp::new_ard(&x, KernelType::Rbf, &[0.5, 0.5], 1.0, 0.0);
        let b = KernelOp::new(&x, KernelType::Rbf, 0.5, 1.0, 0.0);
        assert!(a.to_dense().max_abs_diff(&b.to_dense()) < 1e-12);
    }

    #[test]
    fn cross_kernel_matches_square() {
        let x = data(15, 3, 7);
        let op = KernelOp::new(&x, KernelType::Matern32, 0.8, 1.5, 0.0);
        let cross = cross_kernel(&x, &x, KernelType::Matern32, &[0.8, 0.8, 0.8], 1.5);
        assert!(cross.max_abs_diff(&op.to_dense()) < 1e-12);
    }

    #[test]
    fn panel_matmat_matches_naive_reference_property() {
        use crate::util::proptest::{check, Config};
        let kinds =
            [KernelType::Rbf, KernelType::Matern12, KernelType::Matern32, KernelType::Matern52];
        check(Config { cases: 32, seed: 0xBEEF }, "panel matmat == naive", |rng, case| {
            let kind = kinds[case % 4];
            let n = 17 + (case * 13) % 80; // non-divisible sizes
            let d = 1 + case % 5;
            let r = 1 + case % 6;
            let tile = [8, 11, 16, 33][(case / 4) % 4];
            let threads = if case % 2 == 0 { 1 } else { 4 };
            let x = Matrix::randn(n, d, rng);
            let b = Matrix::randn(n, r, rng);
            let op = KernelOp::new(&x, kind, 0.7, 1.3, 0.05)
                .with_tile(tile)
                .with_threads(threads);
            let got = op.matmat(&b);
            let want = op.matmat_naive(&b);
            let diff = got.max_abs_diff(&want);
            crate::prop_assert!(
                diff < 1e-10,
                "kind={kind:?} n={n} d={d} r={r} tile={tile} threads={threads} diff={diff:e}"
            );
            Ok(())
        });
    }

    #[test]
    fn panel_grad_contract_matches_naive_property() {
        use crate::util::proptest::{check, Config};
        let kinds =
            [KernelType::Rbf, KernelType::Matern12, KernelType::Matern32, KernelType::Matern52];
        check(Config { cases: 16, seed: 0xFACE }, "panel grad == naive", |rng, case| {
            let kind = kinds[case % 4];
            let n = 11 + (case * 9) % 60;
            let d = 1 + case % 4;
            let tile = [8, 13, 32][case % 3];
            let threads = if case % 2 == 0 { 1 } else { 4 };
            let x = Matrix::randn(n, d, rng);
            let l: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let r: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let op = KernelOp::new(&x, kind, 0.8, 1.2, 0.0)
                .with_tile(tile)
                .with_threads(threads);
            let (ge, gs) = op.grad_contract(&l, &r);
            let (ne, ns) = op.grad_contract_naive(&l, &r);
            crate::prop_assert!(
                (ge - ne).abs() < 1e-10 * (1.0 + ne.abs()),
                "kind={kind:?} n={n} d={d} ell grad {ge} vs {ne}"
            );
            crate::prop_assert!(
                (gs - ns).abs() < 1e-10 * (1.0 + ns.abs()),
                "kind={kind:?} n={n} d={d} s2 grad {gs} vs {ns}"
            );
            Ok(())
        });
    }

    #[test]
    fn mixed_matmat_tracks_f64_within_f32_forward_error() {
        use crate::linalg::SolveWorkspace;
        let kinds =
            [KernelType::Rbf, KernelType::Matern12, KernelType::Matern32, KernelType::Matern52];
        let x = data(70, 3, 21);
        let mut rng = Pcg64::seeded(22);
        let b = Matrix::randn(70, 4, &mut rng);
        let mut ws = SolveWorkspace::new();
        for kind in kinds {
            for threads in [1, 4] {
                let op =
                    KernelOp::new(&x, kind, 0.7, 1.3, 0.05).with_tile(24).with_threads(threads);
                let want = op.matmat(&b);
                let mut got = Matrix::zeros(70, 4);
                op.matmat_mixed_in(&mut ws, &b, &mut got);
                // f32 storage bounds the per-entry forward error at
                // O(ε₃₂·‖K‖·‖b‖): 5e-4 hybrid, same bound the dispatch
                // sweep documents (tests/simd_dispatch.rs).
                for (g, w) in got.as_slice().iter().zip(want.as_slice()) {
                    assert!(
                        (g - w).abs() < 5e-4 * (1.0 + w.abs()),
                        "{kind:?} threads={threads}: {g} vs {w}"
                    );
                }
            }
            assert!(op_supports(&x, kind));
        }

        fn op_supports(x: &Matrix, kind: KernelType) -> bool {
            KernelOp::new(x, kind, 0.7, 1.3, 0.05).supports_mixed()
        }
    }

    #[test]
    fn mixed_grad_contract_tracks_f64() {
        let x = data(40, 2, 31);
        let mut rng = Pcg64::seeded(32);
        let l: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let r: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let kinds =
            [KernelType::Rbf, KernelType::Matern12, KernelType::Matern32, KernelType::Matern52];
        for kind in kinds {
            let op = KernelOp::new(&x, kind, 0.8, 1.2, 0.0).with_tile(16).with_threads(1);
            let (ge, gs) = op.grad_contract(&l, &r);
            let (me, ms) = op.grad_contract_mixed(&l, &r);
            assert!((ge - me).abs() < 5e-4 * (1.0 + ge.abs()), "{kind:?} ell {me} vs {ge}");
            assert!((gs - ms).abs() < 5e-4 * (1.0 + gs.abs()), "{kind:?} s2 {ms} vs {gs}");
        }
    }

    #[test]
    fn grad_contract_matches_finite_difference() {
        let x = data(12, 2, 8);
        let mut rng = Pcg64::seeded(9);
        let l: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let r: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        for kind in [KernelType::Rbf, KernelType::Matern12, KernelType::Matern32, KernelType::Matern52] {
            let (ell, s2) = (0.8, 1.4);
            let op = KernelOp::new(&x, kind, ell, s2, 0.0);
            let (g_ell, g_s2) = op.grad_contract(&l, &r);
            let f = |ell: f64, s2: f64| -> f64 {
                let o = KernelOp::new(&x, kind, ell, s2, 0.0);
                crate::util::dot(&l, &o.matvec(&r))
            };
            let h: f64 = 1e-5;
            // d/d log ell
            let fd_ell = (f(ell * h.exp(), s2) - f(ell * (-h).exp(), s2)) / (2.0 * h);
            let fd_s2 = (f(ell, s2 * h.exp()) - f(ell, s2 * (-h).exp())) / (2.0 * h);
            assert!(
                (g_ell - fd_ell).abs() < 1e-4 * (1.0 + fd_ell.abs()),
                "{kind:?} ell grad {g_ell} vs fd {fd_ell}"
            );
            assert!(
                (g_s2 - fd_s2).abs() < 1e-4 * (1.0 + fd_s2.abs()),
                "{kind:?} s2 grad {g_s2} vs fd {fd_s2}"
            );
        }
    }
}

//! Pseudo-random number generation and low-discrepancy sequences.
//!
//! Everything is implemented from scratch (the image has no `rand` crate):
//! a PCG64 generator, normal/gamma variates, shuffling, and a Sobol sequence
//! for Bayesian-optimization candidate sets.

mod sobol;
pub use sobol::Sobol;

/// PCG-XSL-RR 128/64 pseudo-random generator (O'Neill 2014).
///
/// 128-bit LCG state, 64-bit xorshift-rotate output. Fast, statistically
/// solid, and trivially seedable — all experiments in this crate are
/// reproducible from a `u64` seed.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream constant fixed).
    pub fn seeded(seed: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: (0xda3e_39cb_94b9_5bdb_u128 << 1) | 1,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        // warm up
        for _ in 0..4 {
            rng.next_u64();
        }
        rng
    }

    /// Derive an independent child generator (for per-thread streams).
    pub fn split(&mut self) -> Pcg64 {
        let s = self.next_u64();
        Pcg64::seeded(s ^ 0x9e37_79b9_7f4a_7c15)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free-enough mapping; bias negligible for our n.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal variate (Box–Muller, cached second value).
    pub fn normal(&mut self) -> f64 {
        // Marsaglia polar method, no caching for simplicity and statelessness.
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Vector of iid standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Gamma(shape `alpha`, rate `beta`) variate (Marsaglia–Tsang, with the
    /// `alpha < 1` boost). Mean is `alpha / beta`.
    pub fn gamma(&mut self, alpha: f64, beta: f64) -> f64 {
        assert!(alpha > 0.0 && beta > 0.0, "gamma params must be positive");
        if alpha < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^{1/a}
            let u = self.uniform().max(1e-300);
            return self.gamma(alpha + 1.0, beta) * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v / beta;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut rng = Pcg64::seeded(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(4);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn gamma_moments() {
        let mut rng = Pcg64::seeded(5);
        for &(a, b) in &[(0.5, 1.0), (2.0, 3.0), (9.0, 0.5)] {
            let n = 40_000;
            let xs: Vec<f64> = (0..n).map(|_| rng.gamma(a, b)).collect();
            let mean = xs.iter().sum::<f64>() / n as f64;
            let expect = a / b;
            assert!(
                (mean - expect).abs() / expect < 0.05,
                "gamma({a},{b}) mean={mean} expect={expect}"
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(6);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::seeded(7);
        let idx = rng.sample_indices(50, 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(idx.iter().all(|&i| i < 50));
    }
}

//! Sobol low-discrepancy sequence (Joe–Kuo direction numbers, dims ≤ 16).
//!
//! Used for the Bayesian-optimization candidate sets (Sec. 5.2 of the paper
//! chooses Thompson-sampling candidates with a space-filling design) and for
//! the Latin-hypercube-like initial designs.

/// Direction-number table: `(degree s, polynomial a, initial m values)` for
/// dimensions 2..=16 (dimension 1 is the van der Corput sequence in base 2).
/// From the Joe & Kuo (2008) `new-joe-kuo-6` tables.
const JOE_KUO: &[(u32, u32, &[u32])] = &[
    (1, 0, &[1]),
    (2, 1, &[1, 3]),
    (3, 1, &[1, 3, 1]),
    (3, 2, &[1, 1, 1]),
    (4, 1, &[1, 1, 3, 3]),
    (4, 4, &[1, 3, 5, 13]),
    (5, 2, &[1, 1, 5, 5, 17]),
    (5, 4, &[1, 1, 5, 5, 5]),
    (5, 7, &[1, 1, 7, 11, 19]),
    (5, 11, &[1, 1, 5, 1, 1]),
    (5, 13, &[1, 1, 1, 3, 11]),
    (5, 14, &[1, 3, 5, 5, 31]),
    (6, 1, &[1, 3, 3, 9, 7, 49]),
    (6, 13, &[1, 1, 1, 15, 21, 21]),
    (6, 16, &[1, 3, 1, 13, 27, 49]),
];

const BITS: usize = 52; // enough precision for f64 in [0,1)

/// Sobol sequence generator over the unit hypercube `[0,1)^d`, `d ≤ 16`.
pub struct Sobol {
    dim: usize,
    /// direction numbers, `v[d][b]` scaled into the top bits of a u64
    v: Vec<[u64; BITS]>,
    /// Gray-code state per dimension
    x: Vec<u64>,
    index: u64,
}

impl Sobol {
    /// Maximum supported dimension.
    pub const MAX_DIM: usize = JOE_KUO.len() + 1;

    /// Create a `dim`-dimensional Sobol generator.
    ///
    /// # Panics
    /// If `dim == 0` or `dim > Sobol::MAX_DIM`.
    pub fn new(dim: usize) -> Self {
        assert!(dim >= 1 && dim <= Self::MAX_DIM, "sobol supports 1..={} dims", Self::MAX_DIM);
        let mut v = Vec::with_capacity(dim);
        // dimension 1: van der Corput — m_i = 1 for all i
        {
            let mut dir = [0u64; BITS];
            for (i, d) in dir.iter_mut().enumerate() {
                *d = 1u64 << (BITS - 1 - i);
            }
            v.push(dir);
        }
        for d in 1..dim {
            let (s, a, m_init) = JOE_KUO[d - 1];
            let s = s as usize;
            let mut m = vec![0u64; BITS];
            for i in 0..s.min(BITS) {
                m[i] = m_init[i] as u64;
            }
            for i in s..BITS {
                // recurrence: m_i = 2 a_1 m_{i-1} ^ 4 a_2 m_{i-2} ^ ... ^ 2^s m_{i-s} ^ m_{i-s}
                let mut mi = m[i - s] ^ (m[i - s] << s);
                for k in 1..s {
                    let ak = (a >> (s - 1 - k)) & 1;
                    if ak == 1 {
                        mi ^= m[i - k] << k;
                    }
                }
                m[i] = mi;
            }
            let mut dir = [0u64; BITS];
            for i in 0..BITS {
                dir[i] = m[i] << (BITS - 1 - i);
            }
            v.push(dir);
        }
        Sobol { dim, v, x: vec![0; dim], index: 0 }
    }

    /// Next point in `[0,1)^dim` (Gray-code order; the first point is 0).
    pub fn next_point(&mut self) -> Vec<f64> {
        let out: Vec<f64> = self
            .x
            .iter()
            .map(|&xi| xi as f64 / (1u64 << BITS) as f64)
            .collect();
        // advance Gray-code state
        let c = (!self.index).trailing_zeros() as usize;
        let c = c.min(BITS - 1);
        for d in 0..self.dim {
            self.x[d] ^= self.v[d][c];
        }
        self.index += 1;
        out
    }

    /// Generate `n` points, skipping the initial all-zeros point.
    pub fn sample(&mut self, n: usize) -> Vec<Vec<f64>> {
        self.next_point(); // drop 0
        (0..n).map(|_| self.next_point()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn in_unit_cube() {
        let mut s = Sobol::new(6);
        for p in s.sample(1000) {
            assert_eq!(p.len(), 6);
            for &x in &p {
                assert!((0.0..1.0).contains(&x));
            }
        }
    }

    #[test]
    fn first_points_dim1_are_van_der_corput() {
        let mut s = Sobol::new(1);
        s.next_point(); // 0
        let pts: Vec<f64> = (0..7).map(|_| s.next_point()[0]).collect();
        // Gray-code ordering of van der Corput: 1/2, 3/4, 1/4, 3/8, 7/8, 5/8, 1/8
        let expect = [0.5, 0.75, 0.25, 0.375, 0.875, 0.625, 0.125];
        for (a, b) in pts.iter().zip(expect.iter()) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn better_than_random_discrepancy_proxy() {
        // Star-discrepancy proxy: max deviation of the empirical CDF of the
        // first coordinate pair from the product measure on a grid.
        let n = 512;
        let mut s = Sobol::new(2);
        let pts = s.sample(n);
        let mut max_dev: f64 = 0.0;
        for gi in 1..8 {
            for gj in 1..8 {
                let (a, b) = (gi as f64 / 8.0, gj as f64 / 8.0);
                let count = pts.iter().filter(|p| p[0] < a && p[1] < b).count();
                let dev = (count as f64 / n as f64 - a * b).abs();
                max_dev = max_dev.max(dev);
            }
        }
        assert!(max_dev < 0.02, "discrepancy proxy too high: {max_dev}");
    }

    #[test]
    fn dims_are_not_identical() {
        let mut s = Sobol::new(8);
        let pts = s.sample(64);
        for d in 1..8 {
            let same = pts.iter().filter(|p| (p[0] - p[d]).abs() < 1e-15).count();
            assert!(same < 8, "dim {d} looks identical to dim 0");
        }
    }
}

//! Service metrics: counters, latency histogram, batch sizes, msMINRES
//! iteration telemetry (the data behind Fig. S7).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Shared metrics for the sampling service.
#[derive(Default)]
pub struct Metrics {
    /// Requests submitted.
    pub submitted: AtomicU64,
    /// Requests completed successfully.
    pub completed: AtomicU64,
    /// Requests failed.
    pub failed: AtomicU64,
    latencies_us: Mutex<Vec<u64>>,
    batch_sizes: Mutex<Vec<usize>>,
    iter_counts: Mutex<Vec<usize>>,
}

impl Metrics {
    /// Record one request's end-to-end latency.
    pub fn record_latency(&self, d: Duration) {
        self.latencies_us.lock().unwrap().push(d.as_micros() as u64);
    }

    /// Record a dispatched batch size.
    pub fn record_batch(&self, size: usize) {
        self.batch_sizes.lock().unwrap().push(size);
    }

    /// Record msMINRES iteration counts (per RHS).
    pub fn record_iters(&self, iters: &[usize]) {
        self.iter_counts.lock().unwrap().extend_from_slice(iters);
    }

    /// Latency percentile in microseconds (p in [0,100]).
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        let mut v = self.latencies_us.lock().unwrap().clone();
        if v.is_empty() {
            return 0;
        }
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    /// Largest batch dispatched.
    pub fn max_batch_size(&self) -> usize {
        self.batch_sizes.lock().unwrap().iter().copied().max().unwrap_or(0)
    }

    /// Mean batch size.
    pub fn mean_batch_size(&self) -> f64 {
        let v = self.batch_sizes.lock().unwrap();
        if v.is_empty() {
            return 0.0;
        }
        v.iter().sum::<usize>() as f64 / v.len() as f64
    }

    /// Histogram of msMINRES iteration counts with the given bucket width —
    /// regenerates Fig. S7 from live service traffic.
    pub fn iteration_histogram(&self, bucket: usize) -> Vec<(usize, usize)> {
        let v = self.iter_counts.lock().unwrap();
        let mut hist: std::collections::BTreeMap<usize, usize> = Default::default();
        for &it in v.iter() {
            *hist.entry((it / bucket.max(1)) * bucket.max(1)).or_default() += 1;
        }
        hist.into_iter().collect()
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "submitted={} completed={} failed={} p50={}us p99={}us mean_batch={:.1}",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(99.0),
            self.mean_batch_size(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_histogram() {
        let m = Metrics::default();
        for us in [100u64, 200, 300, 400, 500] {
            m.record_latency(Duration::from_micros(us));
        }
        assert_eq!(m.latency_percentile_us(0.0), 100);
        assert_eq!(m.latency_percentile_us(50.0), 300);
        assert_eq!(m.latency_percentile_us(100.0), 500);
        m.record_iters(&[5, 12, 13, 27]);
        let h = m.iteration_histogram(10);
        assert_eq!(h, vec![(0, 1), (10, 2), (20, 1)]);
        m.record_batch(3);
        m.record_batch(7);
        assert_eq!(m.max_batch_size(), 7);
        assert!((m.mean_batch_size() - 5.0).abs() < 1e-12);
        assert!(!m.summary().is_empty());
    }
}

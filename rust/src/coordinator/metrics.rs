//! Service metrics: counters plus lock-free log-bucketed histograms
//! ([`crate::obs::AtomicHistogram`]) for request latency, batch sizes, and
//! msMINRES iteration telemetry (the data behind Fig. S7) — fixed memory, no
//! mutex and no allocation on the completion path, percentiles within the
//! histogram's documented ≤ 6.25 % relative error (`obs::hist::REL_ERR`).
//! The cache-aware execution engine's economics live here too: per-shard
//! queue depths, spectral-cache hit/miss counts, MVMs saved by cache reuse,
//! matmat column-work saved by active-column compaction, background-warmer
//! progress, the adaptive batch controller's per-shard ceilings, and the
//! adaptive wait controller's per-shard flush windows (controller state
//! itself lives here so it is observable for free; the per-shard maps stay
//! mutexed — they are touched per flush, not per request).
//!
//! [`Metrics::snapshot`] copies everything into a typed
//! [`MetricsSnapshot`] serializable as JSON or Prometheus text exposition;
//! the legacy one-line [`Metrics::summary`] renders from the same snapshot.
//!
//! The dispatcher's *liveness* is observable too: [`Metrics::dispatcher_wakeups`]
//! counts event-driven wakeups (one per received request) and
//! [`Metrics::timer_fires`] counts flush-deadline expirations. On the async
//! backend both stand perfectly still while the service is idle — the
//! regression test for "zero idle polls".

use crate::linalg::WsStats;
use crate::obs::hist::AtomicHistogram;
use crate::obs::snapshot::MetricsSnapshot;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

/// Shared metrics for the sampling service.
#[derive(Default)]
pub struct Metrics {
    /// Requests submitted.
    pub submitted: AtomicU64,
    /// Requests completed successfully.
    pub completed: AtomicU64,
    /// Requests failed.
    pub failed: AtomicU64,
    /// Batches that reused a cached spectral estimate (zero Lanczos MVMs).
    pub cache_hits: AtomicU64,
    /// Batches that had to run Lanczos eigenvalue estimation.
    pub cache_misses: AtomicU64,
    /// Operators registered or replaced after startup (each drops the old
    /// entry's spectral cache — the cache-invalidation audit trail).
    pub operator_replacements: AtomicU64,
    /// Warm jobs the background warmer completed (context present when the
    /// job finished, whether the warmer built it or a racing batch did).
    pub warmed_operators: AtomicU64,
    /// Warm jobs that failed to build a context (the batch path will retry
    /// inline and surface the error to clients).
    pub warm_failures: AtomicU64,
    /// Pivot-search passes skipped by pivoted-Cholesky warm starts: a
    /// `replace_operator` seeds the new factor with the old version's pivot
    /// order, and each accepted hinted pivot skips one O(n) greedy scan.
    pub warm_starts: AtomicU64,
    /// Solve-workspace buffer checkouts performed by batch flushes.
    pub workspace_checkouts: AtomicU64,
    /// Workspace checkouts that had to heap-allocate. Stands still once the
    /// pool is warm — the zero-allocation steady-state gauge (regression-
    /// tested at the allocator level in `alloc_regression`).
    pub workspace_grows: AtomicU64,
    /// Peak bytes of scratch owned by any single workspace (max across the
    /// pool's workspaces).
    pub workspace_bytes_high_water: AtomicU64,
    /// Eigenvalue-estimation MVMs avoided by cache hits.
    pub saved_mvms: AtomicU64,
    /// Matmat column-work actually performed by compacted block solves.
    pub column_work: AtomicU64,
    /// Column-work an uncompacted solver would have performed
    /// (`iterations × columns` per batch).
    pub column_work_full: AtomicU64,
    /// Dispatcher wakeups that handled a request arrival. Strictly
    /// event-driven on both backends: an idle service adds zero.
    pub dispatcher_wakeups: AtomicU64,
    /// Flush-deadline expirations (timer-wheel fires on the async backend,
    /// deadline `recv_timeout` expirations on the threaded one). A deadline
    /// only exists while some shard holds a pending request, so an idle
    /// service adds zero.
    pub timer_fires: AtomicU64,
    /// Requests served from cached dense `K^{±1/2}` factors (the
    /// batched-dense tier's GEMV path).
    pub dense_solves: AtomicU64,
    /// Requests the dense tier handed back to the msMINRES path because
    /// their operator's Newton–Schulz iteration did not converge (or the
    /// operator's size changed underfoot).
    pub dense_fallbacks: AtomicU64,
    /// Operator versions whose dense factors were built (each is one
    /// element of a batched Newton–Schulz solve).
    pub dense_factor_builds: AtomicU64,
    /// The dense tier's size-class threshold (crossover `N`), recorded at
    /// startup; 0 when the tier is off.
    pub dense_crossover_n: AtomicU64,
    /// Krylov block solves executed in pure f64 (including mixed solves
    /// that fell back).
    pub solves_f64: AtomicU64,
    /// Krylov block solves served by the mixed-precision engine (f32
    /// kernels + f64 iterative refinement) without falling back.
    pub solves_mixed: AtomicU64,
    /// Iterative-refinement sweeps spent by mixed solves (Σ over batches).
    pub refine_sweeps: AtomicU64,
    /// Mixed solves that stagnated and were re-run in pure f64.
    pub precision_fallbacks: AtomicU64,
    /// The service's solver policy, for observability (`Debug` rendering of
    /// [`crate::ciq::SolverPolicy`]); set once at startup.
    policy: Mutex<String>,
    /// End-to-end request latency in µs: lock-free, fixed-memory, O(1)
    /// wait-free record on the completion path.
    latency_hist: AtomicHistogram,
    /// Dispatched batch sizes (same storage; `sum`/`max` are exact).
    batch_hist: AtomicHistogram,
    /// msMINRES iterations per served RHS (Fig. S7 data; exact below 32).
    iter_hist: AtomicHistogram,
    /// Per-shard `(current depth, max depth)` keyed by `"op/Kind"`.
    shard_depths: Mutex<HashMap<String, (usize, usize)>>,
    /// Per-shard adaptive batch ceiling (AIMD state), keyed by `"op/Kind"`.
    /// Absent ⇒ the shard still runs at the static `max_batch`.
    batch_ceilings: Mutex<HashMap<String, usize>>,
    /// Per-shard adaptive flush wait in µs (wait-controller state), keyed by
    /// `"op/Kind"`. Absent ⇒ the shard still runs at the static `max_wait`.
    shard_waits: Mutex<HashMap<String, u64>>,
    /// Requests served per size-class shard under the batched-dense tier,
    /// keyed by `"sz{n}/Kind"`. Pruned (with the rest of the per-shard
    /// maps) when a size class loses its last operator.
    dense_shards: Mutex<HashMap<String, u64>>,
    /// Executor-layer telemetry (parks / wakeups / task polls / wheel
    /// fires) when the async backend runs; unset on the threaded backend.
    /// Set once at startup through a lock-free `OnceLock` — `summary()` and
    /// `snapshot()` no longer take a mutex to read it. The idle-service test
    /// asserts on these *below* the coordinator's own counters: task polls
    /// must not advance while the service is idle.
    exec_stats: OnceLock<Arc<crate::exec::ExecStats>>,
}

impl Metrics {
    /// Record one request's end-to-end latency. Wait-free, allocation-free:
    /// one histogram record (four relaxed atomic RMWs).
    pub fn record_latency(&self, d: Duration) {
        self.latency_hist.record(d.as_micros() as u64);
    }

    /// Record a dispatched batch size. Wait-free, allocation-free.
    pub fn record_batch(&self, size: usize) {
        self.batch_hist.record(size as u64);
    }

    /// Record msMINRES iteration counts (per RHS). Wait-free,
    /// allocation-free.
    pub fn record_iters(&self, iters: &[usize]) {
        for &it in iters {
            self.iter_hist.record(it as u64);
        }
    }

    /// Record a spectral-cache hit and the estimation MVMs it avoided.
    pub fn record_cache_hit(&self, saved_mvms: u64) {
        // ordering: Relaxed — independent telemetry counters; readers only
        // need eventual per-counter totals, never cross-counter consistency.
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
        self.saved_mvms.fetch_add(saved_mvms, Ordering::Relaxed);
    }

    /// Record a spectral-cache miss (Lanczos estimation ran).
    pub fn record_cache_miss(&self) {
        // ordering: Relaxed — telemetry counter, no synchronization implied.
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one batch's matmat column-work: `done` as performed by the
    /// compacted solver, `full` as an uncompacted solver would have paid.
    pub fn record_column_work(&self, done: u64, full: u64) {
        // ordering: Relaxed — telemetry counters; `saved_column_work` already
        // tolerates reading the pair mid-update (saturating_sub).
        self.column_work.fetch_add(done, Ordering::Relaxed);
        self.column_work_full.fetch_add(full, Ordering::Relaxed);
    }

    /// Record one Krylov block solve's precision outcome: which engine
    /// served it, refinement sweeps spent, and whether the mixed attempt
    /// fell back to pure f64 (a fallback counts as an f64 solve — that is
    /// the arithmetic that produced the served answer).
    pub fn record_precision(&self, mixed: bool, sweeps: u64, fallback: bool) {
        // ordering: Relaxed — telemetry counters, no synchronization implied.
        if mixed && !fallback {
            self.solves_mixed.fetch_add(1, Ordering::Relaxed);
        } else {
            self.solves_f64.fetch_add(1, Ordering::Relaxed);
        }
        self.refine_sweeps.fetch_add(sweeps, Ordering::Relaxed);
        if fallback {
            self.precision_fallbacks.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Matmat columns saved by active-column compaction so far.
    pub fn saved_column_work(&self) -> u64 {
        // ordering: Relaxed — monitoring read; a torn pair only skews one
        // report and the subtraction saturates.
        let full = self.column_work_full.load(Ordering::Relaxed);
        full.saturating_sub(self.column_work.load(Ordering::Relaxed))
    }

    /// Fold one returned workspace's drained telemetry into the service
    /// counters (checkouts/grows are deltas, the high-water is a max).
    pub fn record_workspace(&self, stats: &WsStats) {
        // ordering: Relaxed — telemetry deltas/max; publication of the stats
        // themselves rode the workspace checkin that produced `stats`.
        self.workspace_checkouts.fetch_add(stats.checkouts, Ordering::Relaxed);
        self.workspace_grows.fetch_add(stats.grows, Ordering::Relaxed);
        self.workspace_bytes_high_water.fetch_max(stats.bytes_high_water, Ordering::Relaxed);
    }

    /// Install the async dispatcher's executor stats (startup, once). A
    /// second call is a no-op: the first installed handle wins, matching the
    /// one-executor-per-service lifecycle.
    pub fn set_exec_stats(&self, stats: Arc<crate::exec::ExecStats>) {
        let _ = self.exec_stats.set(stats);
    }

    /// The async dispatcher's executor-layer stats, when that backend runs.
    /// Lock-free read of the set-once handle.
    pub fn exec_stats(&self) -> Option<Arc<crate::exec::ExecStats>> {
        self.exec_stats.get().cloned()
    }

    /// Record the service's solver policy (startup, once).
    pub fn set_policy(&self, policy: &str) {
        *self.policy.lock().unwrap() = policy.to_string();
    }

    /// The service's solver policy as recorded at startup.
    pub fn policy(&self) -> String {
        self.policy.lock().unwrap().clone()
    }

    /// A shard's current adaptive batch ceiling, if the controller has ever
    /// touched it.
    pub fn batch_ceiling(&self, shard: &str) -> Option<usize> {
        self.batch_ceilings.lock().unwrap().get(shard).copied()
    }

    /// Snapshot of all adaptive batch ceilings as `(shard, ceiling)`, sorted.
    pub fn batch_ceilings(&self) -> Vec<(String, usize)> {
        let m = self.batch_ceilings.lock().unwrap();
        let mut v: Vec<(String, usize)> = m.iter().map(|(k, &c)| (k.clone(), c)).collect();
        v.sort();
        v
    }

    /// One clamped-AIMD step of a shard's batch ceiling, driven by whether
    /// the observed flush latency overshot the service target: multiplicative
    /// decrease (halve) on overshoot, additive increase (+1) otherwise, with
    /// the result clamped to `[min, max]`. A shard starts at `max` (be
    /// greedy until latency says otherwise). Returns the new ceiling.
    pub fn tune_batch_ceiling(&self, shard: &str, over_target: bool, min: usize, max: usize) -> usize {
        let min = min.max(1);
        let max = max.max(min); // a misconfigured floor above the cap degrades to floor == cap
        let mut m = self.batch_ceilings.lock().unwrap();
        let cur = *m.get(shard).unwrap_or(&max);
        let next = if over_target { (cur / 2).max(min) } else { (cur + 1).min(max) }.clamp(min, max);
        m.insert(shard.to_string(), next);
        next
    }

    /// A shard's current adaptive flush wait, if the wait controller has
    /// ever touched it.
    pub fn shard_wait(&self, shard: &str) -> Option<Duration> {
        self.shard_waits.lock().unwrap().get(shard).map(|&us| Duration::from_micros(us))
    }

    /// Snapshot of all adaptive flush waits as `(shard, wait µs)`, sorted.
    pub fn shard_waits(&self) -> Vec<(String, u64)> {
        let m = self.shard_waits.lock().unwrap();
        let mut v: Vec<(String, u64)> = m.iter().map(|(k, &us)| (k.clone(), us)).collect();
        v.sort();
        v
    }

    /// One wait-controller step for a shard's flush window, driven by how
    /// the batch ended: a **full** flush (the ceiling was hit before the
    /// deadline) shrinks the wait ×3/4 — demand is high, waiting longer only
    /// adds latency; a **short** deadline flush stretches it ×5/4 (+1 µs so
    /// it cannot stick at a rounded-down fixpoint) — the window was too
    /// small to realize batching economics. Clamped to `[floor, cap]`; a
    /// shard starts at `cap` (the static `max_wait` is the latency
    /// ceiling). Returns the new wait.
    pub fn tune_max_wait(
        &self,
        shard: &str,
        full_flush: bool,
        floor: Duration,
        cap: Duration,
    ) -> Duration {
        let floor_us = (floor.as_micros() as u64).max(1);
        // a misconfigured floor above the cap degrades to floor == cap
        let cap_us = (cap.as_micros() as u64).max(floor_us);
        let mut m = self.shard_waits.lock().unwrap();
        let cur = *m.get(shard).unwrap_or(&cap_us);
        let next = if full_flush { (cur * 3) / 4 } else { (cur * 5) / 4 + 1 }.clamp(floor_us, cap_us);
        m.insert(shard.to_string(), next);
        Duration::from_micros(next)
    }

    /// Drop all per-shard state (queue-depth entries, adaptive batch
    /// ceilings, adaptive flush waits, and dense-tier counts) belonging to
    /// operator `op_name` — shard labels are `"op/Kind"`. Called on operator
    /// deregistration so client-visible maps cannot grow without bound
    /// across operator churn.
    pub fn prune_shard(&self, op_name: &str) {
        self.prune_prefix(&format!("{op_name}/"));
    }

    /// Drop every per-shard entry whose label starts with `prefix`: the
    /// generalized prune behind operator deregistration (`"op/"`) and dense
    /// size-class retirement (`"sz{n}/"`, when the last registered operator
    /// of a size class departs).
    pub fn prune_prefix(&self, prefix: &str) {
        self.shard_depths.lock().unwrap().retain(|k, _| !k.starts_with(prefix));
        self.batch_ceilings.lock().unwrap().retain(|k, _| !k.starts_with(prefix));
        self.shard_waits.lock().unwrap().retain(|k, _| !k.starts_with(prefix));
        self.dense_shards.lock().unwrap().retain(|k, _| !k.starts_with(prefix));
    }

    /// Credit `count` dense-tier requests to a size-class shard
    /// (`"sz{n}/Kind"`).
    pub fn record_dense_shard(&self, shard: &str, count: u64) {
        *self.dense_shards.lock().unwrap().entry(shard.to_string()).or_insert(0) += count;
    }

    /// Requests a size-class shard has served from dense factors (0 if
    /// never seen).
    pub fn dense_shard_solves(&self, shard: &str) -> u64 {
        self.dense_shards.lock().unwrap().get(shard).copied().unwrap_or(0)
    }

    /// Snapshot of all dense size-class shards as `(shard, served)`, sorted.
    pub fn dense_shards(&self) -> Vec<(String, u64)> {
        let m = self.dense_shards.lock().unwrap();
        let mut v: Vec<(String, u64)> = m.iter().map(|(k, &c)| (k.clone(), c)).collect();
        v.sort();
        v
    }

    /// Record the dense tier's size-class threshold (startup, once).
    pub fn set_dense_crossover(&self, n: u64) {
        // ordering: Relaxed — telemetry written once at startup before any
        // traffic; readers only need the eventual value.
        self.dense_crossover_n.store(n, Ordering::Relaxed);
    }

    /// Record a shard's current queue depth (also tracks its max). Fast path
    /// avoids the key allocation once the shard has been seen.
    pub fn record_shard_depth(&self, shard: &str, depth: usize) {
        let mut m = self.shard_depths.lock().unwrap();
        if let Some(entry) = m.get_mut(shard) {
            entry.0 = depth;
            entry.1 = entry.1.max(depth);
        } else {
            m.insert(shard.to_string(), (depth, depth));
        }
    }

    /// Mark a shard's queue as drained (current depth 0) **without creating
    /// the entry when absent** — a flush racing a deregistration's
    /// [`Metrics::prune_shard`] must not resurrect the pruned telemetry.
    pub fn record_shard_drained(&self, shard: &str) {
        if let Some(entry) = self.shard_depths.lock().unwrap().get_mut(shard) {
            entry.0 = 0;
        }
    }

    /// A shard's current queue depth (0 if never seen).
    pub fn shard_depth(&self, shard: &str) -> usize {
        self.shard_depths.lock().unwrap().get(shard).map(|e| e.0).unwrap_or(0)
    }

    /// A shard's maximum observed queue depth (0 if never seen).
    pub fn max_shard_depth(&self, shard: &str) -> usize {
        self.shard_depths.lock().unwrap().get(shard).map(|e| e.1).unwrap_or(0)
    }

    /// Snapshot of all shards as `(name, current depth, max depth)`, sorted
    /// by name for stable output.
    pub fn shard_depths(&self) -> Vec<(String, usize, usize)> {
        let m = self.shard_depths.lock().unwrap();
        let mut v: Vec<(String, usize, usize)> =
            m.iter().map(|(k, &(cur, max))| (k.clone(), cur, max)).collect();
        v.sort();
        v
    }

    /// Latency percentile in µs (p in [0,100]), `None` when no request has
    /// completed. The report is the covering bucket's upper bound:
    /// `true <= reported <= true * (1 + obs::hist::REL_ERR)`. O(buckets),
    /// allocation-free, no mutex — the clone-and-sort of the old
    /// `Mutex<Vec<u64>>` storage is gone.
    pub fn latency_percentile(&self, p: f64) -> Option<u64> {
        self.latency_hist.percentile(p)
    }

    /// Legacy-shaped latency percentile: 0 when no data (callers that need
    /// to distinguish "no data" from a true 0 µs sample use
    /// [`Metrics::latency_percentile`]).
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        self.latency_hist.percentile(p).unwrap_or(0)
    }

    /// Largest batch dispatched (exact: the histogram tracks the max aside).
    pub fn max_batch_size(&self) -> usize {
        self.batch_hist.max() as usize
    }

    /// Mean batch size (exact: sum and count are tracked aside).
    pub fn mean_batch_size(&self) -> f64 {
        self.batch_hist.mean()
    }

    /// Mean msMINRES iterations per served RHS (0 if none recorded) — the
    /// number the preconditioned policy is judged on. Exact.
    pub fn mean_iterations(&self) -> f64 {
        self.iter_hist.mean()
    }

    /// Histogram of msMINRES iteration counts with the given bucket width —
    /// regenerates Fig. S7 from live service traffic. Counts below 32
    /// re-bin exactly; above that each log-bucket lands at its upper bound
    /// (≤ 6.25 % high).
    pub fn iteration_histogram(&self, bucket: usize) -> Vec<(usize, usize)> {
        let snap = self.iter_hist.snapshot();
        let w = bucket.max(1);
        let mut hist: std::collections::BTreeMap<usize, usize> = Default::default();
        for (_, hi, c) in snap.buckets() {
            let rep = hi.min(snap.max()) as usize;
            *hist.entry((rep / w) * w).or_default() += c as usize;
        }
        hist.into_iter().collect()
    }

    /// Copy every counter, histogram, per-shard map, and the executor's
    /// counters into a typed, serializable [`MetricsSnapshot`].
    pub fn snapshot(&self) -> MetricsSnapshot {
        // ordering: Relaxed — monitoring snapshot; counters are independent
        // and a report needs no cross-counter consistency.
        let ld = |c: &AtomicU64| c.load(Ordering::Relaxed);
        MetricsSnapshot {
            policy: self.policy(),
            submitted: ld(&self.submitted),
            completed: ld(&self.completed),
            failed: ld(&self.failed),
            cache_hits: ld(&self.cache_hits),
            cache_misses: ld(&self.cache_misses),
            operator_replacements: ld(&self.operator_replacements),
            warmed_operators: ld(&self.warmed_operators),
            warm_failures: ld(&self.warm_failures),
            warm_starts: ld(&self.warm_starts),
            workspace_checkouts: ld(&self.workspace_checkouts),
            workspace_grows: ld(&self.workspace_grows),
            workspace_bytes_high_water: ld(&self.workspace_bytes_high_water),
            saved_mvms: ld(&self.saved_mvms),
            saved_column_work: self.saved_column_work(),
            column_work: ld(&self.column_work),
            dispatcher_wakeups: ld(&self.dispatcher_wakeups),
            timer_fires: ld(&self.timer_fires),
            dense_solves: ld(&self.dense_solves),
            dense_fallbacks: ld(&self.dense_fallbacks),
            dense_factor_builds: ld(&self.dense_factor_builds),
            dense_crossover_n: ld(&self.dense_crossover_n),
            solves_f64: ld(&self.solves_f64),
            solves_mixed: ld(&self.solves_mixed),
            refine_sweeps: ld(&self.refine_sweeps),
            precision_fallbacks: ld(&self.precision_fallbacks),
            latency_us: self.latency_hist.snapshot(),
            batch_sizes: self.batch_hist.snapshot(),
            iterations: self.iter_hist.snapshot(),
            shard_depths: self.shard_depths(),
            batch_ceilings: self.batch_ceilings(),
            shard_waits: self.shard_waits(),
            dense_shards: self.dense_shards(),
            exec: self.exec_stats.get().map(|s| s.snapshot()),
        }
    }

    /// One-line summary for logs (rendered from [`Metrics::snapshot`]).
    pub fn summary(&self) -> String {
        self.snapshot().to_line()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_histogram() {
        let m = Metrics::default();
        // Empty: Option-returning percentile distinguishes "no data" (the
        // old clone-and-sort API returned 0 for both).
        assert_eq!(m.latency_percentile(50.0), None);
        assert_eq!(m.latency_percentile_us(50.0), 0);
        for us in [100u64, 200, 300, 400, 500] {
            m.record_latency(Duration::from_micros(us));
        }
        // Histogram-backed percentiles: within the documented relative-error
        // bound, never below the true sample.
        for (p, truth) in [(0.0, 100u64), (50.0, 300), (100.0, 500)] {
            let got = m.latency_percentile_us(p);
            assert!(got >= truth, "p{p}: {got} < true {truth}");
            let bound = (truth as f64 * (1.0 + crate::obs::hist::REL_ERR)).ceil() as u64;
            assert!(got <= bound, "p{p}: {got} > bound {bound}");
        }
        m.record_iters(&[5, 12, 13, 27]);
        // Iteration counts below 32 are stored exactly, so Fig. S7 re-binning
        // is unchanged from the Vec-backed storage.
        let h = m.iteration_histogram(10);
        assert_eq!(h, vec![(0, 1), (10, 2), (20, 1)]);
        m.record_batch(3);
        m.record_batch(7);
        assert_eq!(m.max_batch_size(), 7);
        assert!((m.mean_batch_size() - 5.0).abs() < 1e-12);
        assert!(!m.summary().is_empty());
    }

    #[test]
    fn snapshot_serializes_and_exec_handle_is_set_once() {
        let m = Metrics::default();
        m.set_policy("Plain");
        m.record_latency(Duration::from_micros(250));
        m.record_batch(4);
        m.record_iters(&[17]);
        m.record_shard_depth("a/Sample", 2);
        let s = m.snapshot();
        assert_eq!(s.policy, "Plain");
        assert_eq!(s.latency_us.count(), 1);
        assert_eq!(s.batch_sizes.max(), 4);
        assert_eq!(s.iterations.count(), 1);
        assert!(s.exec.is_none());
        let json = s.to_json();
        assert!(json.contains("\"policy\":\"Plain\""));
        assert!(json.contains("\"shard_depths\":{\"a/Sample\":[2,2]}"));
        assert!(s.to_prometheus().contains("ciq_batch_size_count 1"));
        assert_eq!(m.summary(), s.to_line());

        // OnceLock semantics: the first installed executor handle wins.
        let e1 = Arc::new(crate::exec::ExecStats::default());
        e1.polls.fetch_add(7, Ordering::Relaxed);
        m.set_exec_stats(e1.clone());
        m.set_exec_stats(Arc::new(crate::exec::ExecStats::default()));
        let got = m.exec_stats().expect("handle installed");
        assert_eq!(got.polls.load(Ordering::Relaxed), 7);
        assert_eq!(m.snapshot().exec.unwrap().polls, 7);
    }

    #[test]
    fn cache_and_shard_telemetry() {
        let m = Metrics::default();
        m.record_cache_miss();
        m.record_cache_hit(15);
        m.record_cache_hit(15);
        assert_eq!(m.cache_hits.load(Ordering::Relaxed), 2);
        assert_eq!(m.cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(m.saved_mvms.load(Ordering::Relaxed), 30);

        m.record_shard_depth("a/Sample", 3);
        m.record_shard_depth("a/Sample", 1);
        m.record_shard_depth("b/Whiten", 2);
        assert_eq!(m.shard_depth("a/Sample"), 1);
        assert_eq!(m.max_shard_depth("a/Sample"), 3);
        assert_eq!(m.shard_depth("b/Whiten"), 2);
        assert_eq!(m.shard_depth("never-seen"), 0);
        let depths = m.shard_depths();
        assert_eq!(depths.len(), 2);
        assert_eq!(depths[0].0, "a/Sample");

        m.record_column_work(30, 60);
        m.record_column_work(10, 10);
        assert_eq!(m.column_work.load(Ordering::Relaxed), 40);
        assert_eq!(m.saved_column_work(), 30);
        assert!(m.summary().contains("cache_hit=2"));
    }

    #[test]
    fn prune_shard_drops_only_that_operators_entries() {
        // Regression: record_shard_depth's map grew unboundedly across
        // operator churn — deregistration must prune the operator's shards.
        let m = Metrics::default();
        m.record_shard_depth("a/Sample", 3);
        m.record_shard_depth("a/Whiten", 1);
        m.record_shard_depth("ab/Sample", 2); // prefix-adjacent name must survive
        m.tune_batch_ceiling("a/Sample", false, 1, 16);
        m.tune_batch_ceiling("ab/Sample", true, 1, 16);
        m.tune_max_wait("a/Sample", true, Duration::from_micros(100), Duration::from_millis(2));
        m.tune_max_wait("ab/Sample", true, Duration::from_micros(100), Duration::from_millis(2));
        m.prune_shard("a");
        assert_eq!(m.shard_depth("a/Sample"), 0);
        assert_eq!(m.max_shard_depth("a/Whiten"), 0);
        assert_eq!(m.shard_depth("ab/Sample"), 2, "unrelated operator pruned");
        assert!(m.batch_ceiling("a/Sample").is_none());
        assert!(m.batch_ceiling("ab/Sample").is_some());
        assert!(m.shard_wait("a/Sample").is_none(), "prune must drop the wait entry");
        assert!(m.shard_wait("ab/Sample").is_some());
        assert_eq!(m.shard_depths().len(), 1);
        // a flush racing the prune must not resurrect the entry…
        m.record_shard_drained("a/Sample");
        assert_eq!(m.shard_depths().len(), 1, "drain resurrected a pruned shard");
        // …while a live shard's drain still zeroes its current depth
        m.record_shard_drained("ab/Sample");
        assert_eq!(m.shard_depth("ab/Sample"), 0);
        assert_eq!(m.max_shard_depth("ab/Sample"), 2);
    }

    #[test]
    fn dense_tier_counters_accumulate_render_and_prune() {
        let m = Metrics::default();
        m.record_dense_shard("sz16/Sample", 8);
        m.record_dense_shard("sz16/Sample", 4);
        m.record_dense_shard("sz64/Whiten", 2);
        assert_eq!(m.dense_shard_solves("sz16/Sample"), 12);
        assert_eq!(m.dense_shard_solves("sz64/Whiten"), 2);
        assert_eq!(m.dense_shard_solves("sz256/Sample"), 0);
        assert_eq!(m.dense_shards().len(), 2);
        // size-class retirement prunes exactly that class across all maps
        m.record_shard_depth("sz16/Sample", 3);
        m.prune_prefix("sz16/");
        assert_eq!(m.dense_shard_solves("sz16/Sample"), 0);
        assert_eq!(m.shard_depth("sz16/Sample"), 0);
        assert_eq!(m.dense_shard_solves("sz64/Whiten"), 2, "unrelated class pruned");
        // tier counters render in the one-line summary
        m.dense_solves.fetch_add(14, Ordering::Relaxed);
        m.dense_fallbacks.fetch_add(3, Ordering::Relaxed);
        m.dense_factor_builds.fetch_add(5, Ordering::Relaxed);
        m.set_dense_crossover(256);
        let s = m.summary();
        assert!(s.contains("dense_solves=14"));
        assert!(s.contains("dense_fallbacks=3"));
        assert!(s.contains("dense_builds=5"));
        assert!(s.contains("dense_crossover_n=256"));
    }

    #[test]
    fn precision_counters_accumulate_and_render() {
        let m = Metrics::default();
        m.record_precision(false, 0, false);
        m.record_precision(true, 3, false);
        m.record_precision(true, 4, true);
        assert_eq!(m.solves_f64.load(Ordering::Relaxed), 2, "fallback counts as f64");
        assert_eq!(m.solves_mixed.load(Ordering::Relaxed), 1);
        assert_eq!(m.refine_sweeps.load(Ordering::Relaxed), 7);
        assert_eq!(m.precision_fallbacks.load(Ordering::Relaxed), 1);
        let s = m.summary();
        assert!(s.contains("solves_mixed=1"));
        assert!(s.contains("precision_fallbacks=1"));
    }

    #[test]
    fn aimd_batch_ceiling_clamps_and_converges() {
        let m = Metrics::default();
        // starts at max, additive increase is capped at max
        assert_eq!(m.tune_batch_ceiling("s", false, 2, 16), 16);
        // overshoot halves...
        assert_eq!(m.tune_batch_ceiling("s", true, 2, 16), 8);
        assert_eq!(m.tune_batch_ceiling("s", true, 2, 16), 4);
        // ...down to the floor, never below
        assert_eq!(m.tune_batch_ceiling("s", true, 2, 16), 2);
        assert_eq!(m.tune_batch_ceiling("s", true, 2, 16), 2);
        // recovery is additive
        assert_eq!(m.tune_batch_ceiling("s", false, 2, 16), 3);
        assert_eq!(m.batch_ceiling("s"), Some(3));
        assert_eq!(m.batch_ceilings(), vec![("s".to_string(), 3)]);
        // policy string round-trips
        m.set_policy("CachedBounds");
        assert_eq!(m.policy(), "CachedBounds");
        assert!(m.summary().contains("policy=CachedBounds"));
    }

    #[test]
    fn workspace_telemetry_accumulates_and_renders() {
        let m = Metrics::default();
        m.record_workspace(&WsStats { checkouts: 10, grows: 4, bytes_high_water: 800 });
        m.record_workspace(&WsStats { checkouts: 7, grows: 0, bytes_high_water: 1200 });
        m.record_workspace(&WsStats { checkouts: 3, grows: 1, bytes_high_water: 600 });
        assert_eq!(m.workspace_checkouts.load(Ordering::Relaxed), 20);
        assert_eq!(m.workspace_grows.load(Ordering::Relaxed), 5);
        // high water is a max across workspaces, not a sum
        assert_eq!(m.workspace_bytes_high_water.load(Ordering::Relaxed), 1200);
        m.warm_starts.fetch_add(9, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("ws_checkouts=20"));
        assert!(s.contains("ws_grows=5"));
        assert!(s.contains("ws_peak_bytes=1200"));
        assert!(s.contains("warm_starts=9"));
    }

    #[test]
    fn wait_controller_shrinks_stretches_and_clamps() {
        let m = Metrics::default();
        let floor = Duration::from_micros(100);
        let cap = Duration::from_micros(4000);
        // starts at the cap; full flushes walk it down multiplicatively
        assert_eq!(m.tune_max_wait("s", true, floor, cap), Duration::from_micros(3000));
        assert_eq!(m.tune_max_wait("s", true, floor, cap), Duration::from_micros(2250));
        // sustained full flushes clamp at the floor, never below
        for _ in 0..20 {
            m.tune_max_wait("s", true, floor, cap);
        }
        assert_eq!(m.shard_wait("s"), Some(floor));
        // short deadline flushes stretch it back up (×5/4 + 1)
        assert_eq!(m.tune_max_wait("s", false, floor, cap), Duration::from_micros(126));
        for _ in 0..40 {
            m.tune_max_wait("s", false, floor, cap);
        }
        // ...and clamp at the cap
        assert_eq!(m.shard_wait("s"), Some(cap));
        assert_eq!(m.shard_waits(), vec![("s".to_string(), 4000)]);
        // a floor above the cap degrades to floor == cap
        let d = m.tune_max_wait("t", true, Duration::from_millis(10), Duration::from_millis(1));
        assert_eq!(d, Duration::from_millis(10));
        // the idle-liveness counters exist and render in the summary
        m.dispatcher_wakeups.fetch_add(3, Ordering::Relaxed);
        m.timer_fires.fetch_add(2, Ordering::Relaxed);
        assert!(m.summary().contains("wakeups=3"));
        assert!(m.summary().contains("timer_fires=2"));
    }
}

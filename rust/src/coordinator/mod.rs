//! The L3 coordinator: a **cache-aware, sharded** batching GP sampling
//! service.
//!
//! A production deployment of this paper looks like a service that answers
//! `K^{1/2} b` (sampling) and `K^{-1/2} b` (whitening) requests against a set
//! of registered covariance operators. The coordinator:
//!
//! * accepts requests over an MPSC channel (each carries its own one-shot
//!   response channel),
//! * routes each request to a **shard** keyed by `(operator, kind)` and
//!   **dynamically batches** within the shard — up to `max_batch` RHS or
//!   `max_wait` of queueing delay — because msMINRES shares its per-iteration
//!   MVMs across a whole batch
//!   ([`crate::krylov::msminres::msminres_block`]), the marginal cost of an
//!   extra RHS is far below a solo solve (this is the knob Fig. 2 mid/right
//!   sweeps),
//! * executes batches on a worker pool sized to the machine,
//! * records per-request latency, batch-size, per-shard queue-depth, and
//!   cache-economics metrics.
//!
//! ## One event/deadline-driven dispatcher
//!
//! **One** thread runs a [`crate::exec`] executor. Channel arrivals are an
//! intake *task* (the mpsc sender unparks the executor — no receive
//! timeout exists at all), and every shard arms its own flush deadline in
//! the executor's timer wheel on first enqueue, firing exactly at
//! `oldest.enqueued + max_wait`. A full batch cancels the armed timer in
//! O(1). An idle service performs **zero** wakeups —
//! [`Metrics::dispatcher_wakeups`] and [`Metrics::timer_fires`] stand
//! still, which a regression test asserts — and a steady sub-`max_wait`
//! trickle can never starve a sub-`max_batch` shard of its flush (the PR 1
//! guarantee, still regression-tested). The pre-`exec` threaded dispatcher
//! that soaked one release as the equivalence baseline is retired; the
//! async executor is the only backend.
//!
//! The dispatcher owns only the *waiting*: batches execute on a FIFO
//! [`TaskPool`] whose workers park on a condvar, and the actual solve
//! compute still fans out through the persistent panel-GEMM chunk pool.
//!
//! ## Zero-allocation steady state
//!
//! Batch workers draw every solve buffer from a lazily-grown
//! [`WorkspacePool`]: one [`crate::linalg::SolveWorkspace`] is checked out
//! per flush
//! (so at most `workers` ever exist), the batch matrix is built in
//! workspace memory, the solve runs through [`Ciq::solve_block_in`] (zero
//! heap allocations once warm — see `rust/DESIGN.md` §4), results are
//! recycled after the responses are sent, and the workspace returns to the
//! pool. Steady traffic therefore performs no per-request allocations
//! below the request envelope (the rhs/response vectors clients own).
//! Telemetry: [`Metrics::workspace_checkouts`], [`Metrics::workspace_grows`]
//! (stands still once warm — regression-tested), and
//! [`Metrics::workspace_bytes_high_water`]. Deregistering an operator
//! prunes the pool's idle buffers along with the shard telemetry.
//!
//! ## Solver policies and per-operator solver contexts
//!
//! The service is configured with a [`SolverPolicy`]
//! ([`ServiceConfig::policy`]) that decides how every batch approaches its
//! operator: `Plain` (inline estimation each batch — the baseline),
//! `CachedBounds` (the default: Lanczos bounds + quadrature rule computed
//! once per operator and reused), or `Preconditioned` (batches run
//! msMINRES-CIQ on the pivoted-Cholesky–whitened operator, Appx. D, and
//! return the rotation-equivalent maps of Eqs. S12/S13 — fewer iterations on
//! ill-conditioned operators at identical sampling semantics). Everything an
//! operator's solves need — bounds, rule, optional preconditioner — lives in
//! one per-operator [`SolverContext`] built by [`Ciq::build_context`] and
//! guarded by a per-operator mutex, so concurrent cold batches wait for one
//! estimation instead of duplicating it. Each context hit is credited with
//! the estimation MVMs the build actually spent (measured, not assumed);
//! [`Metrics::saved_mvms`] totals the savings from live traffic.
//!
//! ## Background warming on a bounded, newest-first pool
//!
//! With [`ServiceConfig::warm_on_register`] (the default), operator
//! contexts are built **off the request path** on a LIFO [`TaskPool`] of
//! [`ServiceConfig::warm_concurrency`] workers: `start`,
//! [`SamplingService::register_operator`] and
//! [`SamplingService::replace_operator`] enqueue the fresh entry, and a
//! burst of registrations warms concurrently (bounded) instead of
//! serializing behind one pivoted-Cholesky build — newest first, because in
//! a replacement burst the newest version is the live one and older queued
//! jobs are skipped as stale. Under the async backend the registration
//! events flow through an executor task (the same arrival-wake machinery as
//! requests) that feeds the pool. The per-operator mutex still serializes a
//! warm build against a racing first batch: whichever gets there first pays
//! the estimation, the other reuses it — a warmed operator's first batch
//! performs **zero** inline estimation MVMs and records a cache hit. Warm
//! completions and failures are visible as [`Metrics::warmed_operators`] /
//! [`Metrics::warm_failures`] (a failed warm is retried inline by the next
//! batch, which surfaces the error to clients). The pool drains on
//! shutdown, after the dispatcher.
//!
//! ## Adaptive per-shard batch ceilings (clamped AIMD)
//!
//! With [`ServiceConfig::adaptive`] set, each shard's effective `max_batch`
//! is steered by the flush latency the workers actually observe: a batch
//! whose solve exceeds [`AdaptiveBatchConfig::target_flush_latency`] halves
//! the shard's ceiling (multiplicative decrease), a batch under target adds
//! one (additive increase), clamped to
//! `[AdaptiveBatchConfig::min_batch, ServiceConfig::max_batch]`. Shards
//! start greedy (at `max_batch`) and converge to the largest batch the
//! latency budget tolerates; the live ceilings are visible via
//! [`Metrics::batch_ceilings`]. Deregistering an operator prunes its shards
//! from the depth, ceiling, and wait maps.
//!
//! ## Adaptive per-shard `max_wait`
//!
//! With [`ServiceConfig::adaptive_wait`] set, the flush deadline itself
//! becomes a controlled variable, steered by how batches end: a **full**
//! flush (depth hit the ceiling before the deadline) means demand is high
//! enough that waiting longer only adds latency — the shard's wait shrinks
//! (×3/4); a **deadline** flush that came up short of the ceiling means the
//! window is too small to realize batching economics — the wait stretches
//! (×5/4), never past the configured `max_wait` (the static value is the
//! latency ceiling, [`AdaptiveWaitConfig::min_wait`] the floor). State
//! lives in [`Metrics::shard_waits`], pruned on deregistration like the
//! batch ceilings.
//!
//! ## Operator replacement versions the cache
//!
//! [`SamplingService::replace_operator`] (and
//! [`SamplingService::register_operator`]) installs a **fresh**
//! operator entry whose solver context starts empty, so a re-registered
//! operator can never be served stale Lanczos bounds, a stale quadrature
//! rule, or a stale preconditioner. Batches already in flight hold an `Arc`
//! to the *old* entry and finish against the consistent (old operator, old
//! context) pair; the old entry — context included — is dropped when the
//! last of them completes.

pub mod metrics;

pub use metrics::Metrics;

use crate::ciq::dense_sqrt::{newton_schulz_stack_in, DenseFactorPair, DenseFactorStack};
use crate::ciq::{self, BatchedDenseConfig, Ciq, CiqOptions, SolveKind, SolverContext, SolverPolicy};
use crate::exec;
use crate::linalg::batched::gemv_gather;
use crate::linalg::WorkspacePool;
use crate::obs::trace::EventKind;
use crate::operators::LinearOp;
use crate::util::threadpool::{TaskOrder, TaskPool};
use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// What the client wants computed.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ReqKind {
    /// `K^{1/2} b` — drawing a sample with covariance `K` from white noise.
    Sample,
    /// `K^{-1/2} b` — whitening `b` against `K`.
    Whiten,
}

/// A shared covariance operator registered with the service.
pub type SharedOp = Arc<dyn LinearOp + Send + Sync>;

/// A registered operator plus its lazily-filled solver context.
///
/// The context is a `Mutex<Option<…>>` rather than a `OnceLock` deliberately:
/// holding the lock across the estimation makes the background warmer and a
/// concurrent cold batch on the same operator *serialize* — whoever arrives
/// second waits for the first build instead of redundantly re-running it.
struct OpEntry {
    op: SharedOp,
    /// `(context, MVMs the one-time build actually spent)` — hits credit
    /// exactly what the build paid, even when Lanczos broke out early.
    context: Mutex<Option<(Arc<SolverContext>, u64)>>,
    /// Pivoted-Cholesky warm-start hint: the *previous* operator version's
    /// pivot order, captured at `replace_operator` time. The context build
    /// seeds the new factor's candidate permutation from it, skipping
    /// pivot-search passes ([`Metrics::warm_starts`] counts the savings).
    precond_hint: Option<Vec<usize>>,
    /// Cached dense `K^{±1/2}` factors under the batched-dense tier, built
    /// once per operator *version* (replacement installs a fresh entry, so
    /// stale factors can never serve a new operator — the same versioning
    /// contract as `context`). A cached `converged = false` pair marks the
    /// version dense-incapable: every flush routes its requests straight to
    /// the msMINRES fallback without re-running the iteration.
    dense: Mutex<Option<Arc<DenseFactorPair>>>,
}

impl OpEntry {
    fn fresh(op: SharedOp) -> Arc<OpEntry> {
        Self::fresh_with_hint(op, None)
    }

    fn fresh_with_hint(op: SharedOp, precond_hint: Option<Vec<usize>>) -> Arc<OpEntry> {
        Arc::new(OpEntry {
            op,
            context: Mutex::new(None),
            precond_hint,
            dense: Mutex::new(None),
        })
    }
}

/// The live operator registry, shared by the service handle, the
/// dispatcher, and the batch workers. Entries are swapped whole on
/// replacement, never mutated in place.
type OpMap = Arc<RwLock<HashMap<String, Arc<OpEntry>>>>;

/// Which queue family a request routes to. Krylov-served requests batch
/// per operator (msMINRES shares its per-iteration MVMs only within one
/// operator); dense-tier requests batch per **size class** — any mix of
/// small operators of the same `n` flushes as one batched GEMV, which is
/// where the tier's cross-operator economics come from.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
enum ShardId {
    /// Per-operator shard (the Krylov path).
    Op(String),
    /// Cross-operator size-class shard (the batched-dense tier).
    SizeClass(usize),
}

/// Shard key: requests are queued and batched per `(shard id, kind)`.
type ShardKey = (ShardId, ReqKind);

/// A warm job: the fresh entry registered under `name`.
type WarmJob = (String, Arc<OpEntry>);

fn shard_label(op_name: &str, kind: ReqKind) -> String {
    format!("{op_name}/{kind:?}")
}

fn size_class_label(n: usize, kind: ReqKind) -> String {
    format!("sz{n}/{kind:?}")
}

fn shard_id_label(id: &ShardId, kind: ReqKind) -> String {
    match id {
        ShardId::Op(name) => shard_label(name, kind),
        ShardId::SizeClass(n) => size_class_label(*n, kind),
    }
}

/// One request.
struct Request {
    /// Globally unique id ([`crate::obs::trace::next_request_id`]) correlating
    /// this request's flight-recorder events across threads.
    id: u64,
    op_name: String,
    kind: ReqKind,
    rhs: Vec<f64>,
    enqueued: Instant,
    respond: Sender<crate::Result<Vec<f64>>>,
}

/// Configuration of the clamped-AIMD per-shard batch controller.
#[derive(Clone, Debug)]
pub struct AdaptiveBatchConfig {
    /// Flush latency the controller steers every shard toward: a batch solve
    /// slower than this halves the shard's ceiling, a faster one adds 1.
    pub target_flush_latency: Duration,
    /// Floor the ceiling can never drop below (the cap is the service's
    /// static `max_batch`).
    pub min_batch: usize,
}

impl Default for AdaptiveBatchConfig {
    fn default() -> Self {
        AdaptiveBatchConfig { target_flush_latency: Duration::from_millis(50), min_batch: 1 }
    }
}

/// Configuration of the queueing-delay-aware per-shard `max_wait`
/// controller (see the module docs: full flushes shrink the wait,
/// short deadline flushes stretch it, within
/// `[min_wait, ServiceConfig::max_wait]`).
#[derive(Clone, Debug)]
pub struct AdaptiveWaitConfig {
    /// Floor the per-shard wait can never shrink below.
    pub min_wait: Duration,
}

impl Default for AdaptiveWaitConfig {
    fn default() -> Self {
        AdaptiveWaitConfig { min_wait: Duration::from_micros(200) }
    }
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Max RHS per batch (the hard cap; also the adaptive controller's
    /// starting ceiling).
    pub max_batch: usize,
    /// Max time a request may wait for batch-mates (the cap when
    /// `adaptive_wait` is on).
    pub max_wait: Duration,
    /// Worker threads executing batches.
    pub workers: usize,
    /// CIQ solver options.
    pub ciq: CiqOptions,
    /// How batches approach their operators (see the module docs).
    pub policy: SolverPolicy,
    /// Build solver contexts on the background warm pool at
    /// registration/replacement time instead of inline on the first batch.
    /// Ignored under `SolverPolicy::Plain` (nothing to warm).
    pub warm_on_register: bool,
    /// Warm pool workers: how many operator contexts may build
    /// concurrently after a burst of registrations.
    pub warm_concurrency: usize,
    /// Per-shard adaptive batch ceilings; `None` keeps the static
    /// `max_batch` everywhere.
    pub adaptive: Option<AdaptiveBatchConfig>,
    /// Per-shard adaptive flush deadlines; `None` keeps the static
    /// `max_wait` everywhere.
    pub adaptive_wait: Option<AdaptiveWaitConfig>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            workers: 2,
            ciq: CiqOptions::default(),
            policy: SolverPolicy::CachedBounds,
            warm_on_register: true,
            warm_concurrency: 2,
            adaptive: None,
            adaptive_wait: None,
        }
    }
}

/// Handle to a running sampling service.
pub struct SamplingService {
    /// The service configuration (the handle consults the policy on
    /// deregistration to decide whether a dense size class emptied).
    config: Arc<ServiceConfig>,
    tx: Option<exec::channel::Sender<Request>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    metrics: Arc<Metrics>,
    ops: OpMap,
    /// Registration events routed through the executor's warm-router task
    /// (`None` when warming is disabled or the policy is `Plain`).
    warm_tx: Option<exec::channel::Sender<WarmJob>>,
    /// Bounded newest-first warm pool (`None` when warming is disabled or
    /// the policy is `Plain`).
    warm_pool: Option<Arc<TaskPool>>,
    /// Per-flush solve workspaces shared by the batch workers.
    workspaces: Arc<WorkspacePool>,
}

/// A pending response.
pub struct Ticket {
    rx: Receiver<crate::Result<Vec<f64>>>,
}

impl Ticket {
    /// Block until the result arrives.
    pub fn wait(self) -> crate::Result<Vec<f64>> {
        self.rx
            .recv()
            .unwrap_or_else(|_| Err(crate::Error::Runtime("service dropped request".into())))
    }
}

struct Batch {
    op_name: String,
    kind: ReqKind,
    requests: Vec<Request>,
}

impl SamplingService {
    /// Start the service with a set of named operators. When warming is
    /// enabled (default), every initial operator is queued to the warm pool
    /// immediately.
    pub fn start(config: ServiceConfig, ops: HashMap<String, SharedOp>) -> SamplingService {
        // Service-wide precision policy: the `CIQ_PRECISION` env override is
        // applied once at startup so both solver tiers (Krylov and batched
        // dense) see the same policy. Applying it here — not inside the
        // solver — keeps unit tests that build `Ciq` directly on pure f64.
        let mut config = config;
        if let Some(p) = crate::linalg::mixed::env_precision_override() {
            config.ciq.precision = p;
            if let SolverPolicy::BatchedDense(cfg) = &mut config.policy {
                cfg.precision = p;
            }
        }
        let entries: HashMap<String, Arc<OpEntry>> =
            ops.into_iter().map(|(name, op)| (name, OpEntry::fresh(op))).collect();
        let registry: OpMap = Arc::new(RwLock::new(entries));
        let metrics = Arc::new(Metrics::default());
        metrics.set_policy(&format!("{:?}", config.policy));
        if let SolverPolicy::BatchedDense(cfg) = &config.policy {
            metrics.set_dense_crossover(cfg.n_threshold as u64);
        }
        let config = Arc::new(config);

        // bounded newest-first warm pool: builds solver contexts off the
        // request path, several at a time under registration bursts
        let warm = config.warm_on_register && config.policy != SolverPolicy::Plain;
        let warm_pool = if warm {
            Some(Arc::new(TaskPool::new(
                "ciq-warm",
                config.warm_concurrency.max(1),
                TaskOrder::Lifo,
            )))
        } else {
            None
        };

        let workspaces = Arc::new(WorkspacePool::new());
        let (tx, rx) = exec::channel::channel::<Request>();
        let (warm_tx, warm_rx) = if warm_pool.is_some() {
            let (a, b) = exec::channel::channel::<WarmJob>();
            (Some(a), Some(b))
        } else {
            (None, None)
        };
        let (c, r, m, w) =
            (config.clone(), registry.clone(), metrics.clone(), workspaces.clone());
        let wp = warm_pool.clone();
        let dispatcher =
            std::thread::spawn(move || dispatcher_async(c, r, rx, warm_rx, wp, m, w));

        let svc = SamplingService {
            config,
            tx: Some(tx),
            dispatcher: Some(dispatcher),
            metrics,
            ops: registry,
            warm_tx,
            warm_pool,
            workspaces,
        };
        if warm {
            let initial: Vec<WarmJob> = svc
                .ops
                .read()
                .unwrap()
                .iter()
                .map(|(name, entry)| (name.clone(), entry.clone()))
                .collect();
            for (name, entry) in initial {
                svc.enqueue_warm(name, entry);
            }
        }
        svc
    }

    /// Hand a fresh entry to the warm machinery through the executor's
    /// warm-router task. No-op when warming is off.
    fn enqueue_warm(&self, name: String, entry: Arc<OpEntry>) {
        if let Some(wtx) = &self.warm_tx {
            let _ = wtx.send((name, entry));
        }
    }

    /// Register a new operator under `name`, or atomically **replace** an
    /// existing one. Replacement installs a fresh entry whose solver context
    /// starts empty — stale bounds/quadrature/preconditioner from the old
    /// operator can never serve the new one (the versioning contract in the
    /// module docs) — and hands the fresh entry to the warm pool so the
    /// rebuild happens off the request path.
    pub fn replace_operator(&self, name: &str, op: SharedOp) {
        // ordering: Relaxed — telemetry counter; the registry RwLock below
        // carries all synchronization for the replacement itself.
        self.metrics.operator_replacements.fetch_add(1, Ordering::Relaxed);
        // Warm-start hint: if the outgoing version already built a
        // preconditioned context for a same-size operator, seed the fresh
        // build with its pivot order (a hyperparameter-step replacement
        // barely moves the greedy pivots). The hint is advisory — the build
        // falls back to the full greedy scan the moment it stops holding.
        // The registry read guard is dropped *before* touching the entry's
        // context mutex: a warmer may hold that mutex across a long build,
        // and blocking on it under the registry lock would stall the
        // dispatcher's per-arrival registry reads behind a queued writer.
        let old_entry = self.ops.read().unwrap().get(name).cloned();
        let hint = old_entry.and_then(|old| {
            if old.op.size() != op.size() {
                return None;
            }
            // try_lock: a warmer may hold the context mutex across a long
            // build; the hint is advisory, so skip it rather than stall the
            // replacement behind a build for the version being replaced.
            let guard = old.context.try_lock().ok()?;
            guard
                .as_ref()
                .and_then(|(ctx, _)| ctx.precond.as_ref().map(|pc| pc.pivot_order().to_vec()))
        });
        let entry = OpEntry::fresh_with_hint(op, hint);
        self.ops.write().unwrap().insert(name.to_string(), entry.clone());
        self.enqueue_warm(name.to_string(), entry);
    }

    /// Alias of [`Self::replace_operator`] for first-time registration after
    /// startup.
    pub fn register_operator(&self, name: &str, op: SharedOp) {
        self.replace_operator(name, op);
    }

    /// Remove an operator (and its solver context and cached dense
    /// factors — both die with the entry `Arc`); in-flight batches complete
    /// against the entry they already hold. The operator's shards are
    /// pruned from the depth/ceiling/wait telemetry, and under the
    /// batched-dense tier the departing operator's **size class** is pruned
    /// too when it loses its last member — so neither map family can grow
    /// without bound across operator churn. Returns whether the name was
    /// registered.
    pub fn deregister_operator(&self, name: &str) -> bool {
        // The class-emptiness check runs under the same write guard as the
        // removal so a concurrent registration of a same-size operator is
        // ordered either wholly before (class stays) or wholly after (its
        // own shard writes repopulate the pruned maps) this decision.
        let mut map = self.ops.write().unwrap();
        let Some(entry) = map.remove(name) else {
            return false;
        };
        let size = entry.op.size();
        let class_emptied = match &self.config.policy {
            SolverPolicy::BatchedDense(cfg) => {
                size <= cfg.n_threshold && !map.values().any(|e| e.op.size() == size)
            }
            _ => false,
        };
        drop(map);
        self.metrics.prune_shard(name);
        if class_emptied {
            self.metrics.prune_prefix(&format!("sz{size}/"));
        }
        // workload shape changed for good: drop idle workspaces' pooled
        // buffers so scratch sized for the retired operator can't linger
        self.workspaces.prune();
        true
    }

    /// Submit a request; returns a [`Ticket`] to wait on.
    pub fn submit(&self, op_name: &str, kind: ReqKind, rhs: Vec<f64>) -> Ticket {
        let (rtx, rrx) = mpsc::channel();
        let req = Request {
            id: crate::obs::trace::next_request_id(),
            op_name: op_name.to_string(),
            kind,
            rhs,
            // clock: request arrival — end-to-end latency is measured from
            // here to the response send.
            enqueued: Instant::now(),
            respond: rtx,
        };
        crate::trace!(EventKind::Enqueue, req.id, req.kind as u64);
        // ordering: Relaxed — telemetry counter; the request itself rides the
        // channel send, which is the synchronizing edge.
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        // if the dispatcher is gone the Ticket will report the failure
        let _ = self.tx.as_ref().unwrap().send(req);
        Ticket { rx: rrx }
    }

    /// Service metrics.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Graceful shutdown: drains in-flight requests, then the warm pool
    /// (which finishes any builds already in progress first).
    pub fn shutdown(mut self) {
        self.stop_threads();
    }

    fn stop_threads(&mut self) {
        // order matters: close both event channels first (the async
        // executor exits only when its intake *and* warm-router tasks see
        // the close), then join the dispatcher, then drain the warm pool.
        drop(self.tx.take());
        drop(self.warm_tx.take());
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
        drop(self.warm_pool.take());
    }
}

impl Drop for SamplingService {
    fn drop(&mut self) {
        self.stop_threads();
    }
}

/// A shard's effective flush threshold: the AIMD controller's per-shard
/// ceiling when adaptive batching is on (the workers update it from
/// observed flush latency), else the static `max_batch`.
fn effective_ceiling(config: &ServiceConfig, metrics: &Metrics, label: &str) -> usize {
    if config.adaptive.is_some() {
        metrics.batch_ceiling(label).unwrap_or(config.max_batch).min(config.max_batch)
    } else {
        config.max_batch
    }
}

/// A shard's effective flush deadline window: the wait controller's
/// per-shard value when adaptive waits are on, else the static `max_wait`.
fn effective_wait(config: &ServiceConfig, metrics: &Metrics, label: &str) -> Duration {
    if config.adaptive_wait.is_some() {
        metrics.shard_wait(label).unwrap_or(config.max_wait).min(config.max_wait)
    } else {
        config.max_wait
    }
}

/// One wait-controller step, gated on the config knob. `full_flush` means
/// the batch hit its ceiling before the deadline (shrink the wait); a short
/// deadline flush stretches it.
fn tune_wait(config: &ServiceConfig, metrics: &Metrics, label: &str, full_flush: bool) {
    if let Some(aw) = &config.adaptive_wait {
        metrics.tune_max_wait(label, full_flush, aw.min_wait, config.max_wait);
    }
}

// ---------------------------------------------------------------------------
// The dispatcher: one exec thread multiplexing all shards
// ---------------------------------------------------------------------------

/// Everything the async dispatcher's tasks and closures share.
struct DispatchCtx {
    config: Arc<ServiceConfig>,
    ops: OpMap,
    metrics: Arc<Metrics>,
    pool: Arc<TaskPool>,
    workspaces: Arc<WorkspacePool>,
    /// Monotonic shard-incarnation counter (executor thread only). A
    /// deadline task only flushes the incarnation it was armed for: a timer
    /// that fired but was polled *after* a full flush re-created its shard
    /// must not steal the successor's fresh queue.
    shard_gen: Cell<u64>,
}

/// Dispatcher-side shard state for the async backend: the queue plus the
/// cancel handle of the armed flush deadline (armed on first enqueue,
/// cancelled in O(1) by a full flush) and the incarnation tag its deadline
/// task checks before flushing.
struct AShard {
    label: String,
    requests: Vec<Request>,
    timer: Option<exec::TimerCancel>,
    gen: u64,
}

type AsyncShards = Rc<RefCell<HashMap<ShardKey, AShard>>>;

/// Hand a flushed queue to the worker pool: per-operator shards run the
/// Krylov batch path, size-class shards the batched-dense path.
fn dispatch_batch(ctx: &DispatchCtx, key: &ShardKey, label: &str, requests: Vec<Request>) {
    if requests.is_empty() {
        return;
    }
    ctx.metrics.record_batch(requests.len());
    // update-only: must not resurrect a pruned depth entry
    ctx.metrics.record_shard_drained(label);
    let (o, c, m, w) =
        (ctx.ops.clone(), ctx.config.clone(), ctx.metrics.clone(), ctx.workspaces.clone());
    match &key.0 {
        ShardId::Op(name) => {
            let batch = Batch { op_name: name.clone(), kind: key.1, requests };
            ctx.pool.submit(move || execute_batch(&o, &c, batch, &m, &w));
        }
        ShardId::SizeClass(n) => {
            let (n, kind, label) = (*n, key.1, label.to_string());
            ctx.pool.submit(move || execute_dense_batch(&o, &c, n, kind, &label, requests, &m, &w));
        }
    }
}

/// Route one arrival: reject unknown operators, enqueue into the shard,
/// full-flush at the ceiling (cancelling the armed deadline), or arm the
/// shard's flush deadline on first enqueue.
fn route_async(
    handle: &exec::Handle,
    ctx: &Rc<DispatchCtx>,
    shards: &AsyncShards,
    req: Request,
) {
    // Prune-ordering contract: the registry guard spans the membership
    // check and every shard/telemetry write, so anything recorded here for
    // a present operator happens-before a deregistration's prune.
    let registry = ctx.ops.read().unwrap();
    if !registry.contains_key(&req.op_name) {
        // ordering: Relaxed — telemetry; the error reaches the client via the
        // response channel, not via this counter.
        ctx.metrics.failed.fetch_add(1, Ordering::Relaxed);
        let _ = req.respond.send(Err(crate::Error::Invalid(format!(
            "unknown operator '{}'",
            req.op_name
        ))));
        return;
    }
    // Tier selection: under the batched-dense policy, requests for
    // operators at or below the size threshold share a cross-operator
    // size-class shard (the crossover measured by `perf_hotpath` §8);
    // everything else batches per operator on the Krylov path.
    let shard_id = match &ctx.config.policy {
        SolverPolicy::BatchedDense(cfg) => {
            let size = registry[&req.op_name].op.size();
            if size <= cfg.n_threshold {
                ShardId::SizeClass(size)
            } else {
                ShardId::Op(req.op_name.clone())
            }
        }
        _ => ShardId::Op(req.op_name.clone()),
    };
    let key = (shard_id, req.kind);
    let mut st = shards.borrow_mut();
    let shard = st.entry(key.clone()).or_insert_with(|| {
        let gen = ctx.shard_gen.get();
        ctx.shard_gen.set(gen + 1);
        AShard { label: shard_id_label(&key.0, key.1), requests: Vec::new(), timer: None, gen }
    });
    shard.requests.push(req);
    let depth = shard.requests.len();
    ctx.metrics.record_shard_depth(&shard.label, depth);
    let ceiling = effective_ceiling(&ctx.config, &ctx.metrics, &shard.label);
    if depth >= ceiling {
        // full flush: cancel the armed deadline (O(1) in the wheel) and
        // shrink the shard's wait — demand beat the clock
        let mut shard = st.remove(&key).unwrap();
        drop(st);
        if let Some(t) = shard.timer.take() {
            t.cancel();
        }
        crate::trace!(EventKind::FlushFull, shard.requests.len(), shard.requests[0].id);
        // Wait tuning targets Krylov batching economics; size-class shards
        // keep the static window (their flushes are GEMV-bound and the
        // per-op liveness check behind the controller's anti-resurrection
        // contract doesn't map onto a cross-operator label).
        if matches!(key.0, ShardId::Op(_)) {
            tune_wait(&ctx.config, &ctx.metrics, &shard.label, true);
        }
        dispatch_batch(ctx, &key, &shard.label, shard.requests);
    } else if depth == 1 {
        // first enqueue: this shard arms its own flush deadline, exactly
        // `effective_wait` after the oldest request's arrival
        let shard = st.get_mut(&key).unwrap();
        let wait = effective_wait(&ctx.config, &ctx.metrics, &shard.label);
        let deadline = shard.requests[0].enqueued + wait;
        let (sleep, cancel) = handle.timer_at(deadline);
        shard.timer = Some(cancel);
        let fgen = shard.gen;
        drop(st);
        let (fctx, fshards, fkey) = (ctx.clone(), shards.clone(), key.clone());
        handle.spawn(async move {
            if !sleep.await {
                return; // cancelled: a full flush (or shutdown) beat the clock
            }
            let flushed = {
                let mut st = fshards.borrow_mut();
                // only flush the incarnation this timer was armed for: if
                // the timer fired but a full flush (whose cancel arrived
                // too late) re-created the shard before this task polled,
                // the successor owns its own fresh deadline
                if st.get(&fkey).map(|s| s.gen) == Some(fgen) {
                    st.remove(&fkey).map(|mut s| {
                        s.timer = None;
                        s
                    })
                } else {
                    None
                }
            };
            let Some(shard) = flushed else {
                return; // raced a full flush that already emptied the shard
            };
            if shard.requests.is_empty() {
                return;
            }
            crate::trace!(EventKind::FlushDeadline, shard.requests.len(), shard.requests[0].id);
            // ordering: Relaxed — liveness telemetry; the idle-poll test reads
            // it after the service is quiescent (joined/awaited).
            fctx.metrics.timer_fires.fetch_add(1, Ordering::Relaxed);
            // a deadline flush came up short of its ceiling: stretch the
            // wait (guarded against resurrecting pruned telemetry; Op
            // shards only — size-class shards skip wait tuning, see the
            // full-flush path)
            if fctx.config.adaptive_wait.is_some() {
                if let ShardId::Op(op_name) = &fkey.0 {
                    let registry = fctx.ops.read().unwrap();
                    if registry.contains_key(op_name) {
                        tune_wait(&fctx.config, &fctx.metrics, &shard.label, false);
                    }
                }
            }
            dispatch_batch(&fctx, &fkey, &shard.label, shard.requests);
        });
    }
}

fn dispatcher_async(
    config: Arc<ServiceConfig>,
    ops: OpMap,
    mut rx: exec::channel::Receiver<Request>,
    warm_rx: Option<exec::channel::Receiver<WarmJob>>,
    warm_pool: Option<Arc<TaskPool>>,
    metrics: Arc<Metrics>,
    workspaces: Arc<WorkspacePool>,
) {
    let executor = exec::Executor::new();
    let handle = executor.handle();
    // expose executor-layer liveness (parks/wakeups/polls) so tests can pin
    // the zero-idle-work property below the coordinator's own counters
    metrics.set_exec_stats(executor.stats());
    let pool = Arc::new(TaskPool::new("ciq-batch", config.workers.max(1), TaskOrder::Fifo));
    let ctx = Rc::new(DispatchCtx {
        config: config.clone(),
        ops: ops.clone(),
        metrics: metrics.clone(),
        pool,
        workspaces,
        shard_gen: Cell::new(0),
    });
    let shards: AsyncShards = Rc::new(RefCell::new(HashMap::new()));

    // Warm router: registration events arrive like requests (a channel wake,
    // not a poll) and feed the bounded newest-first warm pool. Deliberately
    // routed through the executor rather than submitted straight to the
    // pool: the warmer is an executor task feeding a work pool, so
    // registrations share the dispatcher's single event source and ordering
    // with request traffic.
    if let (Some(mut wrx), Some(wpool)) = (warm_rx, warm_pool) {
        let (wops, wcfg, wmet) = (ops, config, metrics);
        handle.spawn(async move {
            while let Some((name, entry)) = wrx.recv().await {
                let (o, c, m) = (wops.clone(), wcfg.clone(), wmet.clone());
                wpool.submit(move || warm_entry(&name, &entry, &o, &c, &m));
            }
        });
    }

    // intake: one task multiplexing every shard's arrivals
    let (ictx, ishards, ihandle) = (ctx.clone(), shards.clone(), handle.clone());
    handle.spawn(async move {
        while let Some(req) = rx.recv().await {
            // ordering: Relaxed — liveness telemetry, same discipline as
            // `timer_fires` above.
            ictx.metrics.dispatcher_wakeups.fetch_add(1, Ordering::Relaxed);
            route_async(&ihandle, &ictx, &ishards, req);
        }
        // service handle dropped: flush whatever is still queued and cancel
        // the armed deadlines so their tasks retire
        let drained: Vec<(ShardKey, AShard)> = ishards.borrow_mut().drain().collect();
        for (key, mut shard) in drained {
            if let Some(t) = shard.timer.take() {
                t.cancel();
            }
            dispatch_batch(&ictx, &key, &shard.label, shard.requests);
        }
    });

    // runs until intake, warm router, and every deadline task have retired
    executor.run();
    // ctx (and with it the batch pool) drops here: queued batches drain
}

// ---------------------------------------------------------------------------
// Shared solve/warm machinery
// ---------------------------------------------------------------------------

/// Fill `entry`'s context if still empty, returning `(context, estimation
/// MVMs the build spent, whether this call built it)`. The single shared
/// fill path for the batch workers and the background warm pool: holding
/// the per-operator lock across the estimation means whoever arrives second
/// waits instead of duplicating the build. `on_build` fires just before a
/// fallible build starts (the batch path records its cache miss there, so
/// repeated estimation on a failing operator stays visible in telemetry).
/// A build that consumed the entry's pivoted-Cholesky warm-start hint
/// credits the saved pivot-search passes to [`Metrics::warm_starts`].
fn ensure_context(
    entry: &OpEntry,
    solver: &Ciq,
    policy: &SolverPolicy,
    metrics: &Metrics,
    on_build: impl FnOnce(),
) -> crate::Result<(Arc<SolverContext>, u64, bool)> {
    let mut guard = entry.context.lock().unwrap();
    if let Some((ctx, estimation_mvms)) = guard.as_ref() {
        return Ok((ctx.clone(), *estimation_mvms, false));
    }
    on_build();
    // count what the build actually spends (Lanczos may break out early on
    // an invariant subspace) so hits credit the true savings
    let counting = crate::operators::CountingOp::new(entry.op.as_ref());
    let (ctx, saved_passes) =
        solver.build_context_with_hint(&counting, policy, entry.precond_hint.as_deref())?;
    let ctx = Arc::new(ctx);
    if saved_passes > 0 {
        // ordering: Relaxed — telemetry; the built context is published by the
        // OnceLock/entry write, not by this counter.
        metrics.warm_starts.fetch_add(saved_passes as u64, Ordering::Relaxed);
    }
    let estimation_mvms = counting.matvec_count();
    *guard = Some((ctx.clone(), estimation_mvms));
    Ok((ctx, estimation_mvms, true))
}

/// Batch-path wrapper around [`ensure_context`]: records cache hit/miss
/// telemetry (those count *batch* economics — the warm pool never touches
/// them).
fn cached_context(
    entry: &OpEntry,
    solver: &Ciq,
    policy: &SolverPolicy,
    metrics: &Metrics,
) -> crate::Result<Arc<SolverContext>> {
    let (ctx, estimation_mvms, built) =
        ensure_context(entry, solver, policy, metrics, || metrics.record_cache_miss())?;
    if !built {
        metrics.record_cache_hit(estimation_mvms);
    }
    Ok(ctx)
}

/// One warm job: build `entry`'s solver context off the request path. An
/// entry that has already been replaced or deregistered by the time the job
/// runs is skipped — a burst of `replace_operator` calls must not burn full
/// builds on orphaned operator versions while the live one waits (the LIFO
/// pool pops the newest registration first for the same reason).
fn warm_entry(
    name: &str,
    entry: &Arc<OpEntry>,
    ops: &OpMap,
    config: &ServiceConfig,
    metrics: &Metrics,
) {
    let live = ops
        .read()
        .unwrap()
        .get(name)
        .map(|current| Arc::ptr_eq(current, entry))
        .unwrap_or(false);
    if !live {
        return;
    }
    crate::trace!(EventKind::WarmStart, entry.op.size(), 0);
    let solver = Ciq::new(config.ciq.clone());
    match ensure_context(entry, &solver, &config.policy, metrics, || {}) {
        Ok((_, _, built)) => {
            crate::trace!(EventKind::WarmDone, u64::from(built), entry.op.size());
            // ordering: Relaxed — telemetry; warm-start tests spin on this
            // counter but only need eventual visibility, not an edge.
            metrics.warmed_operators.fetch_add(1, Ordering::Relaxed);
        }
        Err(_) => {
            crate::trace!(EventKind::WarmFail, entry.op.size(), 0);
            // the next batch retries inline and surfaces the error
            // ordering: Relaxed — telemetry, same discipline as above.
            metrics.warm_failures.fetch_add(1, Ordering::Relaxed);
        }
    }
}

fn execute_batch(
    ops: &OpMap,
    config: &ServiceConfig,
    batch: Batch,
    metrics: &Metrics,
    workspaces: &WorkspacePool,
) {
    // Pin this batch's (operator, cache) pair up front: a concurrent
    // replace_operator swaps the map entry but cannot mix versions here.
    let entry = match ops.read().unwrap().get(&batch.op_name).cloned() {
        Some(entry) => entry,
        None => {
            for req in batch.requests {
                // ordering: Relaxed — telemetry; the error rides the response
                // channel to the client.
                metrics.failed.fetch_add(1, Ordering::Relaxed);
                let _ = req
                    .respond
                    .send(Err(crate::Error::Invalid(format!("unknown operator '{}'", batch.op_name))));
            }
            return;
        }
    };
    let op = entry.op.clone();
    let n = op.size();
    // validate sizes
    let mut valid = Vec::new();
    for req in batch.requests {
        if req.rhs.len() != n {
            // ordering: Relaxed — telemetry; the error rides the response
            // channel to the client.
            metrics.failed.fetch_add(1, Ordering::Relaxed);
            let _ = req.respond.send(Err(crate::Error::Shape(format!(
                "rhs len {} != operator size {n}",
                req.rhs.len()
            ))));
        } else {
            valid.push(req);
        }
    }
    if valid.is_empty() {
        return;
    }
    let r = valid.len();
    // every solve buffer — the batch matrix included — comes from a pooled
    // workspace: a steady-traffic flush allocates nothing below the request
    // envelope once the workspace is warm
    let mut ws = workspaces.checkout();
    crate::trace!(EventKind::WorkspaceCheckout, r, 0);
    let mut b = ws.take_mat(n, r);
    for (j, req) in valid.iter().enumerate() {
        for i in 0..n {
            b[(i, j)] = req.rhs[i];
        }
    }
    let solver = Ciq::new(config.ciq.clone());
    let kind = match batch.kind {
        ReqKind::Sample => SolveKind::Sqrt,
        ReqKind::Whiten => SolveKind::InvSqrt,
    };
    let ctx_res = match &config.policy {
        // Plain: inline estimation every batch, nothing cached or credited
        SolverPolicy::Plain => solver.build_context(op.as_ref(), &SolverPolicy::Plain).map(Arc::new),
        policy => cached_context(&entry, &solver, policy, metrics),
    };
    // The AIMD clock starts *after* the context is in hand: one-time build
    // cost (or time blocked behind the warm pool's per-operator mutex) is
    // not flush latency and must not halve the shard's ceiling.
    // clock: AIMD feedback measures the solve alone, not queueing or build.
    let flush_started = Instant::now();
    let mut ctx_mixed = false;
    let result = ctx_res.and_then(|ctx| {
        ctx_mixed = ctx.precision.is_mixed();
        solver.solve_block_in(&mut ws, op.as_ref(), &b, kind, &ctx)
    });
    ws.give_mat(b);
    match result {
        Ok(res) => {
            // clamped-AIMD feedback: the observed flush latency steers this
            // shard's batch ceiling toward the service target. The registry
            // read lock is held across check *and* insert: deregistration
            // removes the entry under the write lock and prunes telemetry
            // strictly afterwards, so any tune that observed the key
            // happens-before the prune — a batch racing a deregistration can
            // never resurrect the pruned ceiling entry.
            if let Some(ad) = &config.adaptive {
                let registry = ops.read().unwrap();
                if registry.contains_key(&batch.op_name) {
                    let label = shard_label(&batch.op_name, batch.kind);
                    let over = flush_started.elapsed() > ad.target_flush_latency;
                    metrics.tune_batch_ceiling(&label, over, ad.min_batch, config.max_batch);
                }
            }
            metrics.record_iters(&res.col_iterations);
            // compaction telemetry: matmat columns paid vs the uncompacted
            // `iterations × columns` cost
            let full = res.col_iterations.iter().copied().max().unwrap_or(0) * r;
            metrics.record_column_work(res.column_work as u64, full as u64);
            metrics.record_precision(ctx_mixed, res.refine_sweeps as u64, res.precision_fallback);
            for (j, req) in valid.into_iter().enumerate() {
                // the response vector is the request envelope — the one
                // allocation a request intrinsically owns
                let col = res.solution.col(j);
                let latency = req.enqueued.elapsed();
                metrics.record_latency(latency);
                crate::trace!(EventKind::Respond, req.id, latency.as_micros());
                // ordering: Relaxed — telemetry; the result rides the response
                // channel, which synchronizes with the waiting client.
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                let _ = req.respond.send(Ok(col));
            }
            ciq::recycle_block_result(&mut ws, res);
        }
        Err(e) => {
            // propagate the underlying error kind per request (no rewrap)
            for req in valid {
                // ordering: Relaxed — telemetry; the cloned error rides the
                // response channel to each client.
                metrics.failed.fetch_add(1, Ordering::Relaxed);
                let _ = req.respond.send(Err(e.clone()));
            }
        }
    }
    metrics.record_workspace(&workspaces.checkin(ws));
}

/// One size-class flush under the batched-dense tier. Groups the flush's
/// requests by operator (pinning each operator version once), builds dense
/// `K^{±1/2}` factors for every version not yet cached — **one** batched
/// Newton–Schulz solve covers all of them — then applies every
/// cached-factor request in a single gathered batched GEMV
/// ([`gemv_gather`]): the steady state costs one GEMV per request and
/// zero MVMs against the operator. Requests whose operator vanished get
/// the unknown-operator error; wrong-length right-hand sides get shape
/// errors; and operators whose iteration did not converge (or whose size
/// changed underfoot via `replace_operator`) are re-grouped and executed
/// through [`execute_batch`] — the guaranteed msMINRES fallback, always
/// available because the `BatchedDense` policy builds the same
/// cached-bounds Krylov context per operator.
fn execute_dense_batch(
    ops: &OpMap,
    config: &ServiceConfig,
    class_n: usize,
    kind: ReqKind,
    label: &str,
    requests: Vec<Request>,
    metrics: &Metrics,
    workspaces: &WorkspacePool,
) {
    let dense_cfg = match &config.policy {
        SolverPolicy::BatchedDense(cfg) => cfg.clone(),
        // dispatch only creates size-class shards under BatchedDense; stay
        // well-defined if that ever changes
        _ => BatchedDenseConfig::default(),
    };
    let flush_size = requests.len();
    // Group by operator, pinning each version once: a concurrent
    // replace_operator swaps the map entry but cannot mix versions inside
    // this flush.
    let mut groups: Vec<(Arc<OpEntry>, Vec<Request>)> = Vec::new();
    let mut index: HashMap<String, usize> = HashMap::new();
    for req in requests {
        let slot = match index.get(&req.op_name) {
            Some(&s) => Some(s),
            None => match ops.read().unwrap().get(&req.op_name).cloned() {
                Some(entry) => {
                    groups.push((entry, Vec::new()));
                    index.insert(req.op_name.clone(), groups.len() - 1);
                    Some(groups.len() - 1)
                }
                None => None,
            },
        };
        match slot {
            Some(s) => groups[s].1.push(req),
            None => {
                // ordering: Relaxed — telemetry; the error rides the response
                // channel to the client.
                metrics.failed.fetch_add(1, Ordering::Relaxed);
                let _ = req.respond.send(Err(crate::Error::Invalid(format!(
                    "unknown operator '{}'",
                    req.op_name
                ))));
            }
        }
    }
    // Size changed underfoot: those operator versions go wholesale to the
    // Krylov path, which revalidates per-request shapes itself.
    let (sized, mut fallback): (Vec<_>, Vec<_>) =
        groups.into_iter().partition(|(entry, _)| entry.op.size() == class_n);

    let mut ws = workspaces.checkout();
    crate::trace!(EventKind::WorkspaceCheckout, flush_size, 0);
    // Cold path: materialize + factor every operator version in this flush
    // whose dense pair is missing, as one batched Newton–Schulz solve. The
    // per-entry cache store is brief (never held across the build): two
    // racing flushes may both build a pair — wasted work, never a wrong
    // answer — and within this flush each version is built at most once.
    let to_build: Vec<usize> = sized
        .iter()
        .enumerate()
        .filter(|(_, (entry, _))| entry.dense.lock().unwrap().is_none())
        .map(|(i, _)| i)
        .collect();
    if !to_build.is_empty() {
        let nn = class_n * class_n;
        // build-path allocations are once per operator version, not
        // steady-state
        let mut a_stack = vec![0.0; to_build.len() * nn];
        for (bi, &gi) in to_build.iter().enumerate() {
            let dense = sized[gi].0.op.to_dense();
            a_stack[bi * nn..(bi + 1) * nn].copy_from_slice(dense.as_slice());
        }
        let mut stack = DenseFactorStack::new(class_n, to_build.len());
        newton_schulz_stack_in(
            &mut ws,
            class_n,
            to_build.len(),
            &a_stack,
            &dense_cfg.sqrt_opts(),
            &mut stack,
        );
        crate::trace!(EventKind::DenseFactorBuild, to_build.len(), class_n);
        // ordering: Relaxed — telemetry; the pairs are published by the
        // entry mutex stores below.
        metrics.dense_factor_builds.fetch_add(to_build.len() as u64, Ordering::Relaxed);
        for (bi, &gi) in to_build.iter().enumerate() {
            *sized[gi].0.dense.lock().unwrap() = Some(Arc::new(stack.extract_pair(bi)));
        }
    }

    // Flatten: every request of a converged operator joins the batched
    // apply; non-convergent operators fall back whole.
    let mut flat: Vec<(Arc<DenseFactorPair>, Request)> = Vec::new();
    for (entry, reqs) in sized {
        let pair = entry.dense.lock().unwrap().clone();
        match pair {
            Some(p) if p.converged => {
                for req in reqs {
                    if req.rhs.len() != class_n {
                        // ordering: Relaxed — telemetry; the error rides the
                        // response channel to the client.
                        metrics.failed.fetch_add(1, Ordering::Relaxed);
                        let _ = req.respond.send(Err(crate::Error::Shape(format!(
                            "rhs len {} != operator size {class_n}",
                            req.rhs.len()
                        ))));
                    } else {
                        flat.push((p.clone(), req));
                    }
                }
            }
            _ => fallback.push((entry, reqs)),
        }
    }

    let served = flat.len();
    if served > 0 {
        let mut xs = ws.take_vec(served * class_n);
        let mut ys = ws.take_vec(served * class_n);
        for (ri, (_, req)) in flat.iter().enumerate() {
            xs[ri * class_n..(ri + 1) * class_n].copy_from_slice(&req.rhs);
        }
        {
            let mats: Vec<&[f64]> = flat
                .iter()
                .map(|(pair, _)| match kind {
                    ReqKind::Sample => pair.sqrt.as_slice(),
                    ReqKind::Whiten => pair.invsqrt.as_slice(),
                })
                .collect();
            gemv_gather(class_n, &mats, &xs, &mut ys);
        }
        crate::trace!(EventKind::DenseServe, served, class_n);
        // ordering: Relaxed — telemetry; the results ride the response
        // channels, which synchronize with the waiting clients.
        metrics.dense_solves.fetch_add(served as u64, Ordering::Relaxed);
        metrics.record_dense_shard(label, served as u64);
        for (ri, (_, req)) in flat.into_iter().enumerate() {
            // the response vector is the request envelope — the one
            // allocation a request intrinsically owns
            let sol = ys[ri * class_n..(ri + 1) * class_n].to_vec();
            let latency = req.enqueued.elapsed();
            metrics.record_latency(latency);
            crate::trace!(EventKind::Respond, req.id, latency.as_micros());
            // ordering: Relaxed — telemetry, same discipline as above.
            metrics.completed.fetch_add(1, Ordering::Relaxed);
            let _ = req.respond.send(Ok(sol));
        }
        ws.give_vec(ys);
        ws.give_vec(xs);
    }
    metrics.record_workspace(&workspaces.checkin(ws));

    // Guaranteed fallback: re-group per operator and run the Krylov batch
    // path inline on this worker.
    for (_entry, reqs) in fallback {
        if reqs.is_empty() {
            continue;
        }
        crate::trace!(EventKind::DenseFallback, reqs.len(), class_n);
        // ordering: Relaxed — telemetry counter.
        metrics.dense_fallbacks.fetch_add(reqs.len() as u64, Ordering::Relaxed);
        let op_name = reqs[0].op_name.clone();
        let batch = Batch { op_name, kind, requests: reqs };
        execute_batch(ops, config, batch, metrics, workspaces);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::operators::DenseOp;
    use crate::rng::Pcg64;
    use crate::util::rel_err;

    fn make_op(n: usize, seed: u64) -> (SharedOp, Matrix) {
        let mut rng = Pcg64::seeded(seed);
        let a = Matrix::randn(n, n, &mut rng);
        let mut k = a.matmul(&a.transpose());
        for i in 0..n {
            k[(i, i)] += n as f64 * 0.5;
        }
        (Arc::new(DenseOp::new(k.clone())), k)
    }

    #[test]
    fn roundtrip_whiten_then_sample() {
        let n = 24;
        let (op, _k) = make_op(n, 1);
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), op);
        let cfg = ServiceConfig {
            ciq: CiqOptions { tol: 1e-9, ..Default::default() },
            ..Default::default()
        };
        let svc = SamplingService::start(cfg, ops);
        let mut rng = Pcg64::seeded(2);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let w = svc.submit("k", ReqKind::Whiten, b.clone()).wait().unwrap();
        let s = svc.submit("k", ReqKind::Sample, w).wait().unwrap();
        assert!(rel_err(&s, &b) < 1e-4, "whiten→sample roundtrip");
        svc.shutdown();
    }

    #[test]
    fn mixed_policy_service_answers_and_counts_refined_solves() {
        use crate::linalg::{Precision, RefineConfig};
        let n = 24;
        let (op, _k) = make_op(n, 7);
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), op);
        let cfg = ServiceConfig {
            ciq: CiqOptions {
                tol: 1e-8,
                precision: Precision::Mixed(RefineConfig::default()),
                ..Default::default()
            },
            ..Default::default()
        };
        let svc = SamplingService::start(cfg, ops);
        let mut rng = Pcg64::seeded(8);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let w = svc.submit("k", ReqKind::Whiten, b.clone()).wait().unwrap();
        let s = svc.submit("k", ReqKind::Sample, w).wait().unwrap();
        assert!(rel_err(&s, &b) < 1e-4, "whiten→sample roundtrip under mixed precision");
        let m = svc.metrics();
        let mixed = m.solves_mixed.load(Ordering::Relaxed);
        let f64s = m.solves_f64.load(Ordering::Relaxed);
        assert_eq!(mixed + f64s, 2, "every flush records exactly one precision outcome");
        // a well-conditioned operator must be served by the mixed tier, not
        // the f64 fallback
        assert_eq!(m.precision_fallbacks.load(Ordering::Relaxed), 0);
        assert_eq!(mixed, 2, "both flushes ran refined solves");
        assert!(
            m.refine_sweeps.load(Ordering::Relaxed) >= 1,
            "refined solves must report their sweep counts"
        );
        svc.shutdown();
    }

    #[test]
    fn unknown_operator_errors() {
        let (op, _) = make_op(8, 3);
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), op);
        let svc = SamplingService::start(ServiceConfig::default(), ops);
        let r = svc.submit("nope", ReqKind::Sample, vec![0.0; 8]).wait();
        assert!(r.is_err());
        let r2 = svc.submit("k", ReqKind::Sample, vec![0.0; 3]).wait();
        assert!(r2.is_err());
        svc.shutdown();
    }

    #[test]
    fn cached_operator_performs_zero_estimation_mvms_after_first_batch() {
        use crate::operators::CountingOp;
        let n = 16;
        let mut rng = Pcg64::seeded(40);
        let a = Matrix::randn(n, n, &mut rng);
        let mut kmat = a.matmul(&a.transpose());
        for i in 0..n {
            kmat[(i, i)] += n as f64 * 0.5;
        }
        let counter = Arc::new(CountingOp::new(DenseOp::new(kmat)));
        let shared: SharedOp = counter.clone();
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), shared);
        let cfg = ServiceConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            workers: 1,
            ciq: CiqOptions { tol: 1e-8, ..Default::default() },
            // this test pins the *inline* first-batch estimation semantics,
            // so keep the background warm pool out of the race
            warm_on_register: false,
            ..Default::default()
        };
        let svc = SamplingService::start(cfg, ops);
        let send_round = |rng: &mut Pcg64| {
            let tickets: Vec<Ticket> = (0..4)
                .map(|_| {
                    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                    svc.submit("k", ReqKind::Whiten, b)
                })
                .collect();
            for t in tickets {
                t.wait().unwrap();
            }
        };
        send_round(&mut rng);
        let after_first = counter.matvec_count();
        assert!(after_first > 0, "first batch must run Lanczos estimation");
        send_round(&mut rng);
        send_round(&mut rng);
        assert_eq!(
            counter.matvec_count(),
            after_first,
            "batches against a cached operator must perform zero estimation MVMs"
        );
        let m = svc.metrics();
        assert_eq!(m.cache_misses.load(Ordering::Relaxed), 1);
        assert!(m.cache_hits.load(Ordering::Relaxed) >= 2);
        assert!(m.saved_mvms.load(Ordering::Relaxed) > 0);
        assert!(m.column_work.load(Ordering::Relaxed) > 0);
        svc.shutdown();
    }

    #[test]
    fn replaced_operator_reestimates_bounds() {
        use crate::operators::CountingOp;
        let n = 16;
        let mut rng = Pcg64::seeded(50);
        let mk = |scale: f64, rng: &mut Pcg64| {
            let a = Matrix::randn(n, n, rng);
            let mut k = a.matmul(&a.transpose());
            for i in 0..n {
                k[(i, i)] += n as f64 * scale;
            }
            Arc::new(CountingOp::new(DenseOp::new(k)))
        };
        let old_op = mk(0.5, &mut rng);
        let new_op = mk(4.0, &mut rng); // different spectrum → different bounds
        let mut ops = HashMap::new();
        let shared_old: SharedOp = old_op.clone();
        ops.insert("k".to_string(), shared_old);
        let cfg = ServiceConfig {
            workers: 1,
            ciq: CiqOptions { tol: 1e-6, ..Default::default() },
            // deterministic miss accounting: estimation must happen inline
            warm_on_register: false,
            ..Default::default()
        };
        let svc = SamplingService::start(cfg, ops);
        let rhs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        svc.submit("k", ReqKind::Whiten, rhs.clone()).wait().unwrap();
        let old_after_first = old_op.matvec_count();
        assert!(old_after_first > 0, "first batch must run Lanczos estimation");

        let shared_new: SharedOp = new_op.clone();
        svc.replace_operator("k", shared_new);
        svc.submit("k", ReqKind::Whiten, rhs.clone()).wait().unwrap();
        assert!(
            new_op.matvec_count() > 0,
            "replaced operator must re-estimate its spectral bounds (stale cache would mean zero MVMs)"
        );
        assert_eq!(
            old_op.matvec_count(),
            old_after_first,
            "old operator must not be touched after replacement"
        );
        assert_eq!(svc.metrics().cache_misses.load(Ordering::Relaxed), 2, "one miss per operator version");
        assert_eq!(svc.metrics().operator_replacements.load(Ordering::Relaxed), 1);

        // replacement is also first-time registration
        let extra = mk(1.0, &mut rng);
        let shared_extra: SharedOp = extra.clone();
        svc.register_operator("k2", shared_extra);
        svc.submit("k2", ReqKind::Whiten, rhs).wait().unwrap();
        assert!(extra.matvec_count() > 0);

        // deregistration makes the name unknown again
        assert!(svc.deregister_operator("k2"));
        assert!(!svc.deregister_operator("k2"));
        let r = svc.submit("k2", ReqKind::Whiten, vec![0.0; n]).wait();
        assert!(r.is_err(), "deregistered operator must reject requests");
        svc.shutdown();
    }

    #[test]
    fn warmed_operator_first_batch_performs_zero_inline_estimation_mvms() {
        use crate::operators::CountingOp;
        let n = 16;
        let mut rng = Pcg64::seeded(60);
        let a = Matrix::randn(n, n, &mut rng);
        let mut kmat = a.matmul(&a.transpose());
        for i in 0..n {
            kmat[(i, i)] += n as f64 * 0.5;
        }
        let counter = Arc::new(CountingOp::new(DenseOp::new(kmat)));
        let shared: SharedOp = counter.clone();
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), shared);
        let cfg = ServiceConfig {
            workers: 1,
            ciq: CiqOptions { tol: 1e-8, ..Default::default() },
            ..Default::default() // warm_on_register: true
        };
        let svc = SamplingService::start(cfg, ops);
        // wait on the warm pool's completion signal, not on a sleep guess
        let t0 = Instant::now();
        while svc.metrics().warmed_operators.load(Ordering::Relaxed) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "warm pool never completed");
            std::thread::sleep(Duration::from_millis(2));
        }
        let warm_cost = counter.matvec_count();
        assert!(warm_cost > 0, "warming must run the Lanczos estimation");
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        svc.submit("k", ReqKind::Whiten, b).wait().unwrap();
        assert_eq!(
            counter.matvec_count(),
            warm_cost,
            "a warmed operator's first batch must perform zero inline estimation MVMs"
        );
        let m = svc.metrics();
        assert_eq!(m.cache_misses.load(Ordering::Relaxed), 0, "first batch recorded a miss");
        assert!(m.cache_hits.load(Ordering::Relaxed) >= 1);
        assert!(m.saved_mvms.load(Ordering::Relaxed) >= warm_cost);
        svc.shutdown();
    }

    #[test]
    fn adaptive_ceiling_backs_off_under_slow_flushes_and_prunes_on_deregister() {
        let n = 16;
        let (op, _) = make_op(n, 61);
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), op);
        let cfg = ServiceConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            workers: 1,
            ciq: CiqOptions { tol: 1e-10, ..Default::default() },
            // an impossible target: every flush overshoots, so the ceiling
            // must walk 8 → 4 → 2 and clamp at the floor
            adaptive: Some(AdaptiveBatchConfig {
                target_flush_latency: Duration::from_nanos(1),
                min_batch: 2,
            }),
            ..Default::default()
        };
        let svc = SamplingService::start(cfg, ops);
        let mut rng = Pcg64::seeded(62);
        for _ in 0..4 {
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            svc.submit("k", ReqKind::Whiten, b).wait().unwrap();
        }
        assert_eq!(
            svc.metrics().batch_ceiling("k/Whiten"),
            Some(2),
            "AIMD ceiling did not clamp to the floor under sustained overshoot"
        );
        assert_eq!(svc.metrics().batch_ceilings().len(), 1);
        // deregistration prunes the shard's telemetry (depths + ceilings)
        assert!(svc.deregister_operator("k"));
        assert!(svc.metrics().batch_ceiling("k/Whiten").is_none());
        assert!(svc.metrics().shard_depths().is_empty());
        svc.shutdown();
    }

    #[test]
    fn adaptive_wait_shrinks_on_full_flushes_and_stretches_when_short() {
        // Full flushes (instant bursts of max_batch) must walk the shard's
        // wait down toward the floor; short deadline flushes walk it back up
        // toward the static cap.
        let n = 12;
        let (op, _) = make_op(n, 71);
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), op);
        let max_wait = Duration::from_millis(4);
        let cfg = ServiceConfig {
            max_batch: 4,
            max_wait,
            workers: 1,
            ciq: CiqOptions { tol: 1e-8, ..Default::default() },
            adaptive_wait: Some(AdaptiveWaitConfig { min_wait: Duration::from_micros(100) }),
            ..Default::default()
        };
        let svc = SamplingService::start(cfg, ops);
        let mut rng = Pcg64::seeded(72);
        // bursts of exactly max_batch: every flush is full
        for _ in 0..3 {
            let tickets: Vec<Ticket> = (0..4)
                .map(|_| {
                    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                    svc.submit("k", ReqKind::Whiten, b)
                })
                .collect();
            for t in tickets {
                t.wait().unwrap();
            }
        }
        let after_full = svc.metrics().shard_wait("k/Whiten").expect("wait tuned");
        assert!(after_full < max_wait, "full flushes must shrink the wait: {after_full:?}");
        // singletons: every flush is a short deadline flush
        for _ in 0..8 {
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            svc.submit("k", ReqKind::Whiten, b).wait().unwrap();
        }
        let after_short = svc.metrics().shard_wait("k/Whiten").expect("wait tuned");
        assert!(
            after_short > after_full,
            "short deadline flushes must stretch the wait: {after_full:?} → {after_short:?}"
        );
        assert!(after_short <= max_wait, "wait exceeded the static cap");
        // deregistration prunes the wait telemetry too
        assert!(svc.deregister_operator("k"));
        assert!(svc.metrics().shard_wait("k/Whiten").is_none());
        svc.shutdown();
    }

    #[test]
    fn steady_state_flushes_stop_growing_workspaces() {
        // After warm-up, identical flushes must be served entirely from
        // pooled workspace buffers: `workspace_grows` stands still while
        // `workspace_checkouts` keeps climbing (the allocator-level proof
        // lives in the alloc_regression integration test).
        let n = 20;
        let (op, _) = make_op(n, 81);
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), op);
        let cfg = ServiceConfig {
            max_batch: 4,
            // long deadline: every burst of 4 deterministically flushes as a
            // full width-4 batch, so warm-up provably covers the steady
            // shape (a deadline-split narrower batch would still reuse the
            // pooled buffers, but a never-warmed *wider* one would grow)
            max_wait: Duration::from_millis(250),
            workers: 1,
            ciq: CiqOptions { tol: 1e-8, ..Default::default() },
            ..Default::default()
        };
        let svc = SamplingService::start(cfg, ops);
        let mut rng = Pcg64::seeded(82);
        let send_burst = |rng: &mut Pcg64| {
            let tickets: Vec<Ticket> = (0..4)
                .map(|_| {
                    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                    svc.submit("k", ReqKind::Whiten, b)
                })
                .collect();
            for t in tickets {
                t.wait().unwrap();
            }
        };
        for _ in 0..3 {
            send_burst(&mut rng);
        }
        let m = svc.metrics();
        let grows_warm = m.workspace_grows.load(Ordering::Relaxed);
        let checkouts_warm = m.workspace_checkouts.load(Ordering::Relaxed);
        assert!(grows_warm > 0, "warm-up must have grown the workspace");
        assert!(checkouts_warm > 0);
        for _ in 0..5 {
            send_burst(&mut rng);
        }
        assert_eq!(
            m.workspace_grows.load(Ordering::Relaxed),
            grows_warm,
            "steady-state flushes must perform zero workspace growth"
        );
        assert!(
            m.workspace_checkouts.load(Ordering::Relaxed) > checkouts_warm,
            "steady-state flushes must keep drawing from the pool"
        );
        assert!(m.workspace_bytes_high_water.load(Ordering::Relaxed) > 0);
        svc.shutdown();
    }

    #[test]
    fn replace_operator_warm_starts_preconditioner_from_old_pivots() {
        // Under the Preconditioned policy, replacing an operator must seed
        // the new pivoted-Cholesky build with the previous version's pivot
        // order: Metrics::warm_starts counts the skipped search passes, and
        // the replacement still serves correct results.
        let n = 24;
        let mut rng = Pcg64::seeded(91);
        let (op, k) = make_op(n, 92);
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), op.clone());
        let rank = 6;
        let cfg = ServiceConfig {
            workers: 1,
            ciq: CiqOptions { tol: 1e-9, ..Default::default() },
            policy: SolverPolicy::Preconditioned(crate::ciq::PrecondConfig {
                rank,
                sigma2: Some(1.0),
                build_tol: 1e-14,
            }),
            // deterministic: the first batch builds the context inline
            warm_on_register: false,
            ..Default::default()
        };
        let svc = SamplingService::start(cfg, ops);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        svc.submit("k", ReqKind::Whiten, b.clone()).wait().unwrap();
        assert_eq!(
            svc.metrics().warm_starts.load(Ordering::Relaxed),
            0,
            "first build has no hint to consume"
        );
        // replace with the *same* operator: every hinted pivot must hold
        svc.replace_operator("k", op);
        svc.submit("k", ReqKind::Whiten, b).wait().unwrap();
        assert_eq!(
            svc.metrics().warm_starts.load(Ordering::Relaxed),
            rank as u64,
            "hinted rebuild must skip every pivot-search pass"
        );
        // correctness probe after the warm-started rebuild: the served
        // sampling map R (assembled from unit vectors) must satisfy
        // R Rᵀ = K — the invariant the Eqs. S12/S13 rotation preserves
        // (R R' b ≠ b under preconditioning, so no whiten→sample roundtrip)
        let tickets: Vec<Ticket> = (0..n)
            .map(|j| {
                let mut e = vec![0.0; n];
                e[j] = 1.0;
                svc.submit("k", ReqKind::Sample, e)
            })
            .collect();
        let mut r_mat = Matrix::zeros(n, n);
        for (j, t) in tickets.into_iter().enumerate() {
            let col = t.wait().unwrap();
            for i in 0..n {
                r_mat[(i, j)] = col[i];
            }
        }
        let rrt = r_mat.matmul(&r_mat.transpose());
        let err = (&rrt - &k).fro_norm() / k.fro_norm();
        assert!(err < 1e-2, "warm-started preconditioner drifted: R Rᵀ vs K rel err {err}");
        svc.shutdown();
    }

    #[test]
    fn solve_errors_propagate_original_kind() {
        // q_points = 0 makes quadrature construction fail with Invalid; the
        // old path rewrapped every solve failure as Numerical.
        let (op, _) = make_op(8, 13);
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), op);
        let cfg = ServiceConfig {
            ciq: CiqOptions { q_points: 0, ..Default::default() },
            ..Default::default()
        };
        let svc = SamplingService::start(cfg, ops);
        let r = svc.submit("k", ReqKind::Whiten, vec![1.0; 8]).wait();
        match r {
            Err(crate::Error::Invalid(_)) => {}
            other => panic!("expected the original Invalid error to propagate, got {other:?}"),
        }
        assert_eq!(svc.metrics().failed.load(Ordering::Relaxed), 1);
        svc.shutdown();
    }

    #[test]
    fn concurrent_requests_all_answered_and_batched() {
        let n = 16;
        let (op, k) = make_op(n, 4);
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), op);
        let cfg = ServiceConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            workers: 2,
            ciq: CiqOptions { tol: 1e-9, ..Default::default() },
            ..Default::default()
        };
        let svc = SamplingService::start(cfg, ops);
        let mut rng = Pcg64::seeded(5);
        let reqs: Vec<Vec<f64>> = (0..20).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let tickets: Vec<Ticket> =
            reqs.iter().map(|b| svc.submit("k", ReqKind::Whiten, b.clone())).collect();
        // compare each against the solo exact computation
        let exact_map = crate::linalg::eigen::spd_inv_sqrt(&k).unwrap();
        for (t, b) in tickets.into_iter().zip(&reqs) {
            let got = t.wait().unwrap();
            let exact = exact_map.matvec(b);
            assert!(rel_err(&got, &exact) < 1e-5);
        }
        assert_eq!(svc.metrics().completed.load(Ordering::Relaxed), 20);
        assert!(svc.metrics().max_batch_size() > 1, "batching never kicked in");
        svc.shutdown();
    }

    #[test]
    fn batched_dense_fleet_matches_krylov_with_strictly_fewer_mvms() {
        // The ISSUE 7 acceptance bar: a fleet of small operators served by
        // the batched-dense tier must match the Krylov path to ≤ 1e-6 while
        // performing strictly fewer MVM-equivalent operator invocations —
        // proved with per-operator CountingOp ledgers (`to_dense` delegates
        // uncounted, so the dense tier's steady state reads as zero).
        use crate::operators::CountingOp;
        let n = 16;
        let fleet = 64usize;
        let mut rng = Pcg64::seeded(101);
        let mut dense_ops: HashMap<String, SharedOp> = HashMap::new();
        let mut krylov_ops: HashMap<String, SharedOp> = HashMap::new();
        let mut dense_counters = Vec::new();
        let mut krylov_counters = Vec::new();
        for i in 0..fleet {
            let a = Matrix::randn(n, n, &mut rng);
            let mut k = a.matmul(&a.transpose());
            for d in 0..n {
                k[(d, d)] += n as f64 * 0.5;
            }
            let dc = Arc::new(CountingOp::new(DenseOp::new(k.clone())));
            let kc = Arc::new(CountingOp::new(DenseOp::new(k)));
            let ds: SharedOp = dc.clone();
            let ks: SharedOp = kc.clone();
            dense_ops.insert(format!("op{i}"), ds);
            krylov_ops.insert(format!("op{i}"), ks);
            dense_counters.push(dc);
            krylov_counters.push(kc);
        }
        // q_points 16 puts the quadrature error near 1e-13 for these
        // κ ≈ 10 operators, far inside the 1e-6 comparison budget
        let ciq = CiqOptions { q_points: 16, tol: 1e-12, ..Default::default() };
        let dense_svc = SamplingService::start(
            ServiceConfig {
                max_batch: fleet,
                max_wait: Duration::from_millis(20),
                workers: 1, // serial flushes: each factor is built exactly once
                ciq: ciq.clone(),
                policy: SolverPolicy::BatchedDense(BatchedDenseConfig::default()),
                warm_on_register: false, // keep the MVM ledger all-zero
                ..Default::default()
            },
            dense_ops,
        );
        let krylov_svc = SamplingService::start(
            ServiceConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(2),
                workers: 2,
                ciq,
                warm_on_register: false,
                ..Default::default() // policy: CachedBounds — the reference
            },
            krylov_ops,
        );
        for kind in [ReqKind::Whiten, ReqKind::Sample] {
            let rhs: Vec<Vec<f64>> =
                (0..fleet).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
            let dt: Vec<Ticket> = rhs
                .iter()
                .enumerate()
                .map(|(i, b)| dense_svc.submit(&format!("op{i}"), kind, b.clone()))
                .collect();
            let kt: Vec<Ticket> = rhs
                .iter()
                .enumerate()
                .map(|(i, b)| krylov_svc.submit(&format!("op{i}"), kind, b.clone()))
                .collect();
            for (i, (d, k)) in dt.into_iter().zip(kt).enumerate() {
                let dv = d.wait().unwrap();
                let kv = k.wait().unwrap();
                let err = rel_err(&dv, &kv);
                assert!(err <= 1e-6, "op{i} {kind:?}: dense vs Krylov rel err {err}");
            }
        }
        let dense_mvms: u64 =
            dense_counters.iter().map(|c| c.matvec_count() + c.matmat_col_count()).sum();
        let krylov_mvms: u64 =
            krylov_counters.iter().map(|c| c.matvec_count() + c.matmat_col_count()).sum();
        assert_eq!(dense_mvms, 0, "dense tier must never touch the operators' MVM entry points");
        assert!(
            dense_mvms < krylov_mvms && krylov_mvms > 0,
            "strictly-fewer-MVMs proof: dense {dense_mvms} vs Krylov {krylov_mvms}"
        );
        let m = dense_svc.metrics();
        assert_eq!(m.dense_solves.load(Ordering::Relaxed), 2 * fleet as u64);
        assert_eq!(m.dense_factor_builds.load(Ordering::Relaxed), fleet as u64);
        assert_eq!(m.dense_fallbacks.load(Ordering::Relaxed), 0);
        assert!(m.max_batch_size() > 1, "cross-operator size-class batching never kicked in");
        assert!(m.dense_shard_solves(&format!("sz{n}/Whiten")) >= fleet as u64);
        assert!(m.dense_shard_solves(&format!("sz{n}/Sample")) >= fleet as u64);
        dense_svc.shutdown();
        krylov_svc.shutdown();
    }

    #[test]
    fn deregistering_last_size_class_member_prunes_dense_shard_state() {
        let n = 16;
        let (op_a, _) = make_op(n, 111);
        let (op_b, _) = make_op(n, 112);
        let mut ops = HashMap::new();
        ops.insert("a".to_string(), op_a);
        ops.insert("b".to_string(), op_b);
        let cfg = ServiceConfig {
            workers: 1,
            policy: SolverPolicy::BatchedDense(BatchedDenseConfig::default()),
            warm_on_register: false,
            ..Default::default()
        };
        let svc = SamplingService::start(cfg, ops);
        let mut rng = Pcg64::seeded(113);
        for name in ["a", "b"] {
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            svc.submit(name, ReqKind::Whiten, b).wait().unwrap();
        }
        let label = format!("sz{n}/Whiten");
        assert!(svc.metrics().dense_shard_solves(&label) >= 2);
        assert!(svc.metrics().shard_depths().iter().any(|(l, _, _)| l == &label));
        // one member left: the size class survives the first departure
        assert!(svc.deregister_operator("a"));
        assert!(
            svc.metrics().dense_shard_solves(&label) >= 2,
            "class telemetry pruned while a member remains"
        );
        // last member gone: the whole class's telemetry is pruned
        assert!(svc.deregister_operator("b"));
        assert_eq!(svc.metrics().dense_shard_solves(&label), 0);
        assert!(svc.metrics().dense_shards().is_empty());
        assert!(svc.metrics().shard_depths().is_empty());
        svc.shutdown();
    }

    #[test]
    fn operators_above_the_dense_threshold_stay_on_krylov_shards() {
        let small_n = 8;
        let big_n = 24;
        let (small, _) = make_op(small_n, 121);
        let (big, kbig) = make_op(big_n, 122);
        let mut ops = HashMap::new();
        ops.insert("small".to_string(), small);
        ops.insert("big".to_string(), big);
        let cfg = ServiceConfig {
            workers: 1,
            policy: SolverPolicy::BatchedDense(BatchedDenseConfig {
                n_threshold: 16,
                ..Default::default()
            }),
            warm_on_register: false,
            ciq: CiqOptions { tol: 1e-10, ..Default::default() },
            ..Default::default()
        };
        let svc = SamplingService::start(cfg, ops);
        assert_eq!(svc.metrics().dense_crossover_n.load(Ordering::Relaxed), 16);
        let mut rng = Pcg64::seeded(123);
        let bs: Vec<f64> = (0..small_n).map(|_| rng.normal()).collect();
        svc.submit("small", ReqKind::Whiten, bs).wait().unwrap();
        let bb: Vec<f64> = (0..big_n).map(|_| rng.normal()).collect();
        let got = svc.submit("big", ReqKind::Whiten, bb.clone()).wait().unwrap();
        let exact = crate::linalg::eigen::spd_inv_sqrt(&kbig).unwrap().matvec(&bb);
        assert!(rel_err(&got, &exact) < 1e-5, "Krylov-routed big operator answered wrong");
        let m = svc.metrics();
        assert_eq!(m.dense_solves.load(Ordering::Relaxed), 1, "small op must be dense-served");
        assert_eq!(m.dense_shard_solves(&format!("sz{small_n}/Whiten")), 1);
        assert_eq!(
            m.dense_shard_solves(&format!("sz{big_n}/Whiten")),
            0,
            "an operator above n_threshold must not join a size class"
        );
        assert!(
            m.shard_depths().iter().any(|(l, _, _)| l == "big/Whiten"),
            "big op must batch on its per-operator Krylov shard"
        );
        svc.shutdown();
    }

    #[test]
    fn non_convergent_dense_factor_falls_back_to_krylov() {
        let n = 16;
        let (op, k) = make_op(n, 131);
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), op);
        let cfg = ServiceConfig {
            workers: 1,
            // max_iters = 2 cannot reach the 1e-13 residual on these
            // operators: every factor build is flagged non-convergent, so
            // each flush must take the guaranteed msMINRES fallback — and
            // still answer correctly
            policy: SolverPolicy::BatchedDense(BatchedDenseConfig {
                max_iters: 2,
                ..Default::default()
            }),
            warm_on_register: false,
            ciq: CiqOptions { tol: 1e-10, ..Default::default() },
            ..Default::default()
        };
        let svc = SamplingService::start(cfg, ops);
        let mut rng = Pcg64::seeded(132);
        let exact_map = crate::linalg::eigen::spd_inv_sqrt(&k).unwrap();
        for _ in 0..3 {
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let got = svc.submit("k", ReqKind::Whiten, b.clone()).wait().unwrap();
            assert!(rel_err(&got, &exact_map.matvec(&b)) < 1e-5, "fallback answered wrong");
        }
        let m = svc.metrics();
        assert_eq!(m.dense_solves.load(Ordering::Relaxed), 0);
        assert_eq!(m.dense_fallbacks.load(Ordering::Relaxed), 3);
        assert_eq!(
            m.dense_factor_builds.load(Ordering::Relaxed),
            1,
            "a cached non-convergent pair must not be rebuilt every flush"
        );
        assert_eq!(m.completed.load(Ordering::Relaxed), 3);
        svc.shutdown();
    }
}

//! The L3 coordinator: a **cache-aware, sharded** batching GP sampling
//! service.
//!
//! A production deployment of this paper looks like a service that answers
//! `K^{1/2} b` (sampling) and `K^{-1/2} b` (whitening) requests against a set
//! of registered covariance operators. The coordinator:
//!
//! * accepts requests over an MPSC channel (each carries its own one-shot
//!   response channel),
//! * routes each request to a **shard** keyed by `(operator, kind)` and
//!   **dynamically batches** within the shard — up to `max_batch` RHS or
//!   `max_wait` of queueing delay — because msMINRES shares its per-iteration
//!   MVMs across a whole batch
//!   ([`crate::krylov::msminres::msminres_block`]), the marginal cost of an
//!   extra RHS is far below a solo solve (this is the knob Fig. 2 mid/right
//!   sweeps),
//! * executes batches on a worker pool sized to the machine,
//! * records per-request latency, batch-size, per-shard queue-depth, and
//!   cache-economics metrics.
//!
//! ## Shard flushing is deadline-driven
//!
//! The dispatcher's `recv` timeout is computed from the **oldest pending
//! request's flush deadline** across all shards (not a fixed `max_wait` after
//! the most recent arrival), and expired shards are flushed after *every*
//! received request. This matters under steady load: a trickle of requests
//! arriving faster than `max_wait` used to keep the receive loop on its `Ok`
//! path forever, so a sub-`max_batch` queue was never flushed until the
//! trickle stopped (flush starvation). Now a request waits at most
//! `max_wait` (plus solve time) regardless of arrival pattern.
//!
//! ## Solver policies and per-operator solver contexts
//!
//! The service is configured with a [`SolverPolicy`]
//! ([`ServiceConfig::policy`]) that decides how every batch approaches its
//! operator: `Plain` (inline estimation each batch — the baseline),
//! `CachedBounds` (the default: Lanczos bounds + quadrature rule computed
//! once per operator and reused), or `Preconditioned` (batches run
//! msMINRES-CIQ on the pivoted-Cholesky–whitened operator, Appx. D, and
//! return the rotation-equivalent maps of Eqs. S12/S13 — fewer iterations on
//! ill-conditioned operators at identical sampling semantics). Everything an
//! operator's solves need — bounds, rule, optional preconditioner — lives in
//! one per-operator [`SolverContext`] built by [`Ciq::build_context`] and
//! guarded by a per-operator mutex, so concurrent cold batches wait for one
//! estimation instead of duplicating it. Each context hit is credited with
//! the estimation MVMs the build actually spent (measured, not assumed);
//! [`Metrics::saved_mvms`] totals the savings from live traffic.
//!
//! ## Background spectral warmer
//!
//! With [`ServiceConfig::warm_on_register`] (the default), a dedicated
//! warmer thread populates each operator's [`SolverContext`] **off the
//! request path**: `start`, [`SamplingService::register_operator`] and
//! [`SamplingService::replace_operator`] enqueue the fresh entry to the
//! warmer, which builds the context (Lanczos bounds + optional
//! pivoted-Cholesky factorization) while the service keeps serving. The
//! per-operator mutex makes the warmer and a racing first batch serialize:
//! whichever gets there first pays the estimation, the other reuses it — a
//! warmed operator's first batch therefore performs **zero** inline
//! estimation MVMs and records a cache hit. Warm completions and failures
//! are visible as [`Metrics::warmed_operators`] / [`Metrics::warm_failures`]
//! (a failed warm is retried inline by the next batch, which surfaces the
//! error to clients). The warmer drains and exits on shutdown, after the
//! dispatcher.
//!
//! ## Adaptive per-shard batch ceilings (clamped AIMD)
//!
//! With [`ServiceConfig::adaptive`] set, each shard's effective `max_batch`
//! is steered by the flush latency the workers actually observe: a batch
//! whose solve exceeds [`AdaptiveBatchConfig::target_flush_latency`] halves
//! the shard's ceiling (multiplicative decrease), a batch under target adds
//! one (additive increase), clamped to
//! `[AdaptiveBatchConfig::min_batch, ServiceConfig::max_batch]`. Shards
//! start greedy (at `max_batch`) and converge to the largest batch the
//! latency budget tolerates; the live ceilings are visible via
//! [`Metrics::batch_ceilings`]. Deregistering an operator prunes its shards
//! from both the depth and ceiling maps.
//!
//! ## Operator replacement versions the cache
//!
//! [`SamplingService::replace_operator`] (and
//! [`SamplingService::register_operator`]) installs a **fresh**
//! operator entry whose solver context starts empty, so a re-registered
//! operator can never be served stale Lanczos bounds, a stale quadrature
//! rule, or a stale preconditioner. Batches already in flight hold an `Arc`
//! to the *old* entry and finish against the consistent (old operator, old
//! context) pair; the old entry — context included — is dropped when the
//! last of them completes.

pub mod metrics;

pub use metrics::Metrics;

use crate::ciq::{Ciq, CiqOptions, SolveKind, SolverContext, SolverPolicy};
use crate::linalg::Matrix;
use crate::operators::LinearOp;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// What the client wants computed.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ReqKind {
    /// `K^{1/2} b` — drawing a sample with covariance `K` from white noise.
    Sample,
    /// `K^{-1/2} b` — whitening `b` against `K`.
    Whiten,
}

/// A shared covariance operator registered with the service.
pub type SharedOp = Arc<dyn LinearOp + Send + Sync>;

/// A registered operator plus its lazily-filled solver context.
///
/// The context is a `Mutex<Option<…>>` rather than a `OnceLock` deliberately:
/// holding the lock across the estimation makes the background warmer and a
/// concurrent cold batch on the same operator *serialize* — whoever arrives
/// second waits for the first build instead of redundantly re-running it.
struct OpEntry {
    op: SharedOp,
    /// `(context, MVMs the one-time build actually spent)` — hits credit
    /// exactly what the build paid, even when Lanczos broke out early.
    context: Mutex<Option<(Arc<SolverContext>, u64)>>,
}

impl OpEntry {
    fn fresh(op: SharedOp) -> Arc<OpEntry> {
        Arc::new(OpEntry { op, context: Mutex::new(None) })
    }
}

/// The live operator registry, shared by the service handle, the
/// dispatcher, and the batch workers. Entries are swapped whole on
/// replacement, never mutated in place.
type OpMap = Arc<RwLock<HashMap<String, Arc<OpEntry>>>>;

/// Shard key: requests are queued and batched per `(operator, kind)`.
type ShardKey = (String, ReqKind);

fn shard_label(op_name: &str, kind: ReqKind) -> String {
    format!("{op_name}/{kind:?}")
}

/// One request.
struct Request {
    op_name: String,
    kind: ReqKind,
    rhs: Vec<f64>,
    enqueued: Instant,
    respond: Sender<crate::Result<Vec<f64>>>,
}

/// Configuration of the clamped-AIMD per-shard batch controller.
#[derive(Clone, Debug)]
pub struct AdaptiveBatchConfig {
    /// Flush latency the controller steers every shard toward: a batch solve
    /// slower than this halves the shard's ceiling, a faster one adds 1.
    pub target_flush_latency: Duration,
    /// Floor the ceiling can never drop below (the cap is the service's
    /// static `max_batch`).
    pub min_batch: usize,
}

impl Default for AdaptiveBatchConfig {
    fn default() -> Self {
        AdaptiveBatchConfig { target_flush_latency: Duration::from_millis(50), min_batch: 1 }
    }
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Max RHS per batch (the hard cap; also the adaptive controller's
    /// starting ceiling).
    pub max_batch: usize,
    /// Max time a request may wait for batch-mates.
    pub max_wait: Duration,
    /// Worker threads executing batches.
    pub workers: usize,
    /// CIQ solver options.
    pub ciq: CiqOptions,
    /// How batches approach their operators (see the module docs).
    pub policy: SolverPolicy,
    /// Build solver contexts on a background warmer thread at
    /// registration/replacement time instead of inline on the first batch.
    /// Ignored under `SolverPolicy::Plain` (nothing to warm).
    pub warm_on_register: bool,
    /// Per-shard adaptive batch ceilings; `None` keeps the static
    /// `max_batch` everywhere.
    pub adaptive: Option<AdaptiveBatchConfig>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            workers: 2,
            ciq: CiqOptions::default(),
            policy: SolverPolicy::CachedBounds,
            warm_on_register: true,
            adaptive: None,
        }
    }
}

/// Handle to a running sampling service.
pub struct SamplingService {
    tx: Option<Sender<Request>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    metrics: Arc<Metrics>,
    ops: OpMap,
    /// Feed of fresh `(name, entry)` pairs to the background warmer (`None`
    /// when warming is disabled or the policy is `Plain`).
    warmer_tx: Option<Sender<(String, Arc<OpEntry>)>>,
    warmer: Option<std::thread::JoinHandle<()>>,
}

/// A pending response.
pub struct Ticket {
    rx: Receiver<crate::Result<Vec<f64>>>,
}

impl Ticket {
    /// Block until the result arrives.
    pub fn wait(self) -> crate::Result<Vec<f64>> {
        self.rx
            .recv()
            .unwrap_or_else(|_| Err(crate::Error::Runtime("service dropped request".into())))
    }
}

struct Batch {
    op_name: String,
    kind: ReqKind,
    requests: Vec<Request>,
}

impl SamplingService {
    /// Start the service with a set of named operators. When warming is
    /// enabled (default), every initial operator is queued to the background
    /// warmer immediately.
    pub fn start(config: ServiceConfig, ops: HashMap<String, SharedOp>) -> SamplingService {
        let entries: HashMap<String, Arc<OpEntry>> =
            ops.into_iter().map(|(name, op)| (name, OpEntry::fresh(op))).collect();
        let registry: OpMap = Arc::new(RwLock::new(entries));
        let (tx, rx) = mpsc::channel::<Request>();
        let metrics = Arc::new(Metrics::default());
        metrics.set_policy(&format!("{:?}", config.policy));

        // background warmer: builds solver contexts off the request path
        let warm = config.warm_on_register && config.policy != SolverPolicy::Plain;
        let (warmer_tx, warmer) = if warm {
            let (wtx, wrx) = mpsc::channel::<(String, Arc<OpEntry>)>();
            let r = registry.clone();
            let ciq_opts = config.ciq.clone();
            let policy = config.policy.clone();
            let m = metrics.clone();
            let handle = std::thread::spawn(move || warmer_loop(wrx, r, ciq_opts, policy, m));
            for (name, entry) in registry.read().unwrap().iter() {
                let _ = wtx.send((name.clone(), entry.clone()));
            }
            (Some(wtx), Some(handle))
        } else {
            (None, None)
        };

        let m2 = metrics.clone();
        let r2 = registry.clone();
        let dispatcher = std::thread::spawn(move || dispatcher_loop(config, r2, rx, m2));
        SamplingService {
            tx: Some(tx),
            dispatcher: Some(dispatcher),
            metrics,
            ops: registry,
            warmer_tx,
            warmer,
        }
    }

    /// Register a new operator under `name`, or atomically **replace** an
    /// existing one. Replacement installs a fresh entry whose solver context
    /// starts empty — stale bounds/quadrature/preconditioner from the old
    /// operator can never serve the new one (the versioning contract in the
    /// module docs) — and hands the fresh entry to the background warmer so
    /// the rebuild happens off the request path.
    pub fn replace_operator(&self, name: &str, op: SharedOp) {
        self.metrics.operator_replacements.fetch_add(1, Ordering::Relaxed);
        let entry = OpEntry::fresh(op);
        self.ops.write().unwrap().insert(name.to_string(), entry.clone());
        if let Some(wtx) = &self.warmer_tx {
            let _ = wtx.send((name.to_string(), entry));
        }
    }

    /// Alias of [`Self::replace_operator`] for first-time registration after
    /// startup.
    pub fn register_operator(&self, name: &str, op: SharedOp) {
        self.replace_operator(name, op);
    }

    /// Remove an operator (and its solver context); in-flight batches
    /// complete against the entry they already hold. The operator's shards
    /// are pruned from the depth/ceiling telemetry so those maps cannot grow
    /// without bound across operator churn. Returns whether the name was
    /// registered.
    pub fn deregister_operator(&self, name: &str) -> bool {
        let removed = self.ops.write().unwrap().remove(name).is_some();
        if removed {
            self.metrics.prune_shard(name);
        }
        removed
    }

    /// Submit a request; returns a [`Ticket`] to wait on.
    pub fn submit(&self, op_name: &str, kind: ReqKind, rhs: Vec<f64>) -> Ticket {
        let (rtx, rrx) = mpsc::channel();
        let req = Request {
            op_name: op_name.to_string(),
            kind,
            rhs,
            enqueued: Instant::now(),
            respond: rtx,
        };
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        // if the dispatcher is gone the Ticket will report the failure
        let _ = self.tx.as_ref().unwrap().send(req);
        Ticket { rx: rrx }
    }

    /// Service metrics.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Graceful shutdown: drains in-flight requests, then retires the
    /// warmer (it finishes any build already in progress first).
    pub fn shutdown(mut self) {
        self.stop_threads();
    }

    fn stop_threads(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
        drop(self.warmer_tx.take());
        if let Some(h) = self.warmer.take() {
            let _ = h.join();
        }
    }
}

impl Drop for SamplingService {
    fn drop(&mut self) {
        self.stop_threads();
    }
}

/// Dispatcher-side shard: pending requests plus the precomputed metrics
/// label (built once per shard, not once per arrival).
struct Shard {
    label: String,
    requests: Vec<Request>,
}

/// Send one shard's queue off as a batch.
fn flush_shard(
    key: &ShardKey,
    shards: &mut HashMap<ShardKey, Shard>,
    btx: &Sender<Batch>,
    metrics: &Metrics,
) {
    if let Some(shard) = shards.remove(key) {
        if shard.requests.is_empty() {
            return;
        }
        metrics.record_batch(shard.requests.len());
        // update-only: flushing a queue that raced a deregistration's
        // prune_shard must not resurrect the pruned depth entry
        metrics.record_shard_drained(&shard.label);
        let _ = btx.send(Batch { op_name: key.0.clone(), kind: key.1, requests: shard.requests });
    }
}

/// Flush every shard whose oldest request has waited at least `max_wait`,
/// and return the earliest flush deadline still pending — the single source
/// of truth for the dispatcher's next recv timeout.
fn flush_expired(
    shards: &mut HashMap<ShardKey, Shard>,
    max_wait: Duration,
    btx: &Sender<Batch>,
    metrics: &Metrics,
) -> Option<Instant> {
    let now = Instant::now();
    let expired: Vec<ShardKey> = shards
        .iter()
        .filter(|(_, s)| s.requests.first().map(|r| r.enqueued + max_wait <= now).unwrap_or(false))
        .map(|(k, _)| k.clone())
        .collect();
    for key in expired {
        flush_shard(&key, shards, btx, metrics);
    }
    shards.values().filter_map(|s| s.requests.first().map(|r| r.enqueued + max_wait)).min()
}

fn dispatcher_loop(
    config: ServiceConfig,
    ops: OpMap,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
) {
    // worker pool
    let (btx, brx) = mpsc::channel::<Batch>();
    let brx = Arc::new(Mutex::new(brx));
    let stop = Arc::new(AtomicBool::new(false));
    let mut workers = Vec::new();
    for _ in 0..config.workers.max(1) {
        let brx = brx.clone();
        let ops = ops.clone();
        let metrics = metrics.clone();
        let cfg = config.clone();
        let stop = stop.clone();
        workers.push(std::thread::spawn(move || loop {
            let batch = {
                let guard = brx.lock().unwrap();
                match guard.recv_timeout(Duration::from_millis(20)) {
                    Ok(b) => b,
                    Err(RecvTimeoutError::Timeout) => {
                        if stop.load(Ordering::Acquire) {
                            return;
                        }
                        continue;
                    }
                    Err(RecvTimeoutError::Disconnected) => return,
                }
            };
            execute_batch(&ops, &cfg, batch, &metrics);
        }));
    }

    // sharded batching loop: one queue per (operator, kind)
    let idle_poll = Duration::from_millis(50);
    let mut shards: HashMap<ShardKey, Shard> = HashMap::new();
    // Deadline-aware receive: wake when the *oldest pending* request's flush
    // deadline expires, never a fixed max_wait after the most recent arrival.
    let mut next_deadline: Option<Instant> = None;
    loop {
        let timeout = next_deadline
            .map(|deadline| deadline.saturating_duration_since(Instant::now()))
            .unwrap_or(idle_poll);
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                {
                    // The registry guard spans the membership check *and* the
                    // shard/telemetry writes: deregistration removes the map
                    // entry under the write lock and prunes telemetry strictly
                    // afterwards, so anything recorded here for a present
                    // operator happens-before that prune and cannot be
                    // resurrected state.
                    let registry = ops.read().unwrap();
                    if !registry.contains_key(&req.op_name) {
                        // Rejected up front: no shard is created, so
                        // client-controlled names cannot grow the shard map or
                        // its metrics without bound.
                        metrics.failed.fetch_add(1, Ordering::Relaxed);
                        let _ = req.respond.send(Err(crate::Error::Invalid(format!(
                            "unknown operator '{}'",
                            req.op_name
                        ))));
                    } else {
                        let key = (req.op_name.clone(), req.kind);
                        let shard = shards.entry(key.clone()).or_insert_with(|| Shard {
                            label: shard_label(&key.0, key.1),
                            requests: Vec::new(),
                        });
                        shard.requests.push(req);
                        let depth = shard.requests.len();
                        metrics.record_shard_depth(&shard.label, depth);
                        // Effective flush threshold: the AIMD controller's
                        // per-shard ceiling when adaptive batching is on (the
                        // workers update it from observed flush latency), else
                        // the static max_batch.
                        let ceiling = if config.adaptive.is_some() {
                            metrics.batch_ceiling(&shard.label).unwrap_or(config.max_batch).min(config.max_batch)
                        } else {
                            config.max_batch
                        };
                        if depth >= ceiling {
                            flush_shard(&key, &mut shards, &btx, &metrics);
                        }
                    }
                }
                // Deadlines are re-checked after *every* arrival — a steady
                // trickle faster than max_wait can no longer starve a
                // sub-max_batch shard of its flush — but the O(shards) scan
                // only runs once the known earliest deadline has passed (a
                // new arrival's own deadline, now + max_wait, is never the
                // one expiring; a stale-early deadline from a max_batch flush
                // just wakes the loop once ahead of time and self-corrects).
                match next_deadline {
                    Some(deadline) if deadline > Instant::now() => {}
                    _ => {
                        next_deadline =
                            flush_expired(&mut shards, config.max_wait, &btx, &metrics);
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                next_deadline = flush_expired(&mut shards, config.max_wait, &btx, &metrics);
            }
            Err(RecvTimeoutError::Disconnected) => {
                // drain remaining
                let keys: Vec<ShardKey> = shards.keys().cloned().collect();
                for key in keys {
                    flush_shard(&key, &mut shards, &btx, &metrics);
                }
                break;
            }
        }
    }
    drop(btx);
    stop.store(true, Ordering::Release);
    for w in workers {
        let _ = w.join();
    }
}

/// Fill `entry`'s context if still empty, returning `(context, estimation
/// MVMs the build spent, whether this call built it)`. The single shared
/// fill path for the batch workers and the background warmer: holding the
/// per-operator lock across the estimation means whoever arrives second
/// waits instead of duplicating the build. `on_build` fires just before a
/// fallible build starts (the batch path records its cache miss there, so
/// repeated estimation on a failing operator stays visible in telemetry).
fn ensure_context(
    entry: &OpEntry,
    solver: &Ciq,
    policy: &SolverPolicy,
    on_build: impl FnOnce(),
) -> crate::Result<(Arc<SolverContext>, u64, bool)> {
    let mut guard = entry.context.lock().unwrap();
    if let Some((ctx, estimation_mvms)) = guard.as_ref() {
        return Ok((ctx.clone(), *estimation_mvms, false));
    }
    on_build();
    // count what the build actually spends (Lanczos may break out early on
    // an invariant subspace) so hits credit the true savings
    let counting = crate::operators::CountingOp::new(entry.op.as_ref());
    let ctx = Arc::new(solver.build_context(&counting, policy)?);
    let estimation_mvms = counting.matvec_count();
    *guard = Some((ctx.clone(), estimation_mvms));
    Ok((ctx, estimation_mvms, true))
}

/// Batch-path wrapper around [`ensure_context`]: records cache hit/miss
/// telemetry (those count *batch* economics — the warmer never touches
/// them).
fn cached_context(
    entry: &OpEntry,
    solver: &Ciq,
    policy: &SolverPolicy,
    metrics: &Metrics,
) -> crate::Result<Arc<SolverContext>> {
    let (ctx, estimation_mvms, built) =
        ensure_context(entry, solver, policy, || metrics.record_cache_miss())?;
    if !built {
        metrics.record_cache_hit(estimation_mvms);
    }
    Ok(ctx)
}

/// The background warmer: drains registration events and builds each fresh
/// entry's solver context off the request path. An entry that has already
/// been replaced or deregistered by the time its job is popped is skipped —
/// a burst of `replace_operator` calls must not make the warmer burn full
/// builds on orphaned operator versions while the live one waits. Exits
/// when the service handle drops its sender.
fn warmer_loop(
    rx: Receiver<(String, Arc<OpEntry>)>,
    ops: OpMap,
    ciq_opts: CiqOptions,
    policy: SolverPolicy,
    metrics: Arc<Metrics>,
) {
    let solver = Ciq::new(ciq_opts);
    while let Ok((name, entry)) = rx.recv() {
        let live = ops
            .read()
            .unwrap()
            .get(&name)
            .map(|current| Arc::ptr_eq(current, &entry))
            .unwrap_or(false);
        if !live {
            continue;
        }
        match ensure_context(&entry, &solver, &policy, || {}) {
            Ok(_) => {
                metrics.warmed_operators.fetch_add(1, Ordering::Relaxed);
            }
            Err(_) => {
                // the next batch retries inline and surfaces the error
                metrics.warm_failures.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

fn execute_batch(ops: &OpMap, config: &ServiceConfig, batch: Batch, metrics: &Metrics) {
    // Pin this batch's (operator, cache) pair up front: a concurrent
    // replace_operator swaps the map entry but cannot mix versions here.
    let entry = match ops.read().unwrap().get(&batch.op_name).cloned() {
        Some(entry) => entry,
        None => {
            for req in batch.requests {
                metrics.failed.fetch_add(1, Ordering::Relaxed);
                let _ = req
                    .respond
                    .send(Err(crate::Error::Invalid(format!("unknown operator '{}'", batch.op_name))));
            }
            return;
        }
    };
    let op = entry.op.clone();
    let n = op.size();
    // validate sizes
    let mut valid = Vec::new();
    for req in batch.requests {
        if req.rhs.len() != n {
            metrics.failed.fetch_add(1, Ordering::Relaxed);
            let _ = req.respond.send(Err(crate::Error::Shape(format!(
                "rhs len {} != operator size {n}",
                req.rhs.len()
            ))));
        } else {
            valid.push(req);
        }
    }
    if valid.is_empty() {
        return;
    }
    let r = valid.len();
    let mut b = Matrix::zeros(n, r);
    for (j, req) in valid.iter().enumerate() {
        for i in 0..n {
            b[(i, j)] = req.rhs[i];
        }
    }
    let solver = Ciq::new(config.ciq.clone());
    let kind = match batch.kind {
        ReqKind::Sample => SolveKind::Sqrt,
        ReqKind::Whiten => SolveKind::InvSqrt,
    };
    let ctx_res = match &config.policy {
        // Plain: inline estimation every batch, nothing cached or credited
        SolverPolicy::Plain => solver.build_context(op.as_ref(), &SolverPolicy::Plain).map(Arc::new),
        policy => cached_context(&entry, &solver, policy, metrics),
    };
    // The AIMD clock starts *after* the context is in hand: one-time build
    // cost (or time blocked behind the warmer's per-operator mutex) is not
    // flush latency and must not halve the shard's ceiling.
    let flush_started = Instant::now();
    let result = ctx_res.and_then(|ctx| solver.solve_block(op.as_ref(), &b, kind, &ctx));
    match result {
        Ok(res) => {
            // clamped-AIMD feedback: the observed flush latency steers this
            // shard's batch ceiling toward the service target. The registry
            // read lock is held across check *and* insert: deregistration
            // removes the entry under the write lock and prunes telemetry
            // strictly afterwards, so any tune that observed the key
            // happens-before the prune — a batch racing a deregistration can
            // never resurrect the pruned ceiling entry.
            if let Some(ad) = &config.adaptive {
                let registry = ops.read().unwrap();
                if registry.contains_key(&batch.op_name) {
                    let label = shard_label(&batch.op_name, batch.kind);
                    let over = flush_started.elapsed() > ad.target_flush_latency;
                    metrics.tune_batch_ceiling(&label, over, ad.min_batch, config.max_batch);
                }
            }
            metrics.record_iters(&res.col_iterations);
            // compaction telemetry: matmat columns paid vs the uncompacted
            // `iterations × columns` cost
            let full = res.col_iterations.iter().copied().max().unwrap_or(0) * r;
            metrics.record_column_work(res.column_work as u64, full as u64);
            for (j, req) in valid.into_iter().enumerate() {
                let col = res.solution.col(j);
                metrics.record_latency(req.enqueued.elapsed());
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                let _ = req.respond.send(Ok(col));
            }
        }
        Err(e) => {
            // propagate the underlying error kind per request (no rewrap)
            for req in valid {
                metrics.failed.fetch_add(1, Ordering::Relaxed);
                let _ = req.respond.send(Err(e.clone()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operators::DenseOp;
    use crate::rng::Pcg64;
    use crate::util::rel_err;

    fn make_op(n: usize, seed: u64) -> (SharedOp, Matrix) {
        let mut rng = Pcg64::seeded(seed);
        let a = Matrix::randn(n, n, &mut rng);
        let mut k = a.matmul(&a.transpose());
        for i in 0..n {
            k[(i, i)] += n as f64 * 0.5;
        }
        (Arc::new(DenseOp::new(k.clone())), k)
    }

    #[test]
    fn roundtrip_whiten_then_sample() {
        let n = 24;
        let (op, _k) = make_op(n, 1);
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), op);
        let cfg = ServiceConfig {
            ciq: CiqOptions { tol: 1e-9, ..Default::default() },
            ..Default::default()
        };
        let svc = SamplingService::start(cfg, ops);
        let mut rng = Pcg64::seeded(2);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let w = svc.submit("k", ReqKind::Whiten, b.clone()).wait().unwrap();
        let s = svc.submit("k", ReqKind::Sample, w).wait().unwrap();
        assert!(rel_err(&s, &b) < 1e-4, "whiten→sample roundtrip");
        svc.shutdown();
    }

    #[test]
    fn unknown_operator_errors() {
        let (op, _) = make_op(8, 3);
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), op);
        let svc = SamplingService::start(ServiceConfig::default(), ops);
        let r = svc.submit("nope", ReqKind::Sample, vec![0.0; 8]).wait();
        assert!(r.is_err());
        let r2 = svc.submit("k", ReqKind::Sample, vec![0.0; 3]).wait();
        assert!(r2.is_err());
        svc.shutdown();
    }

    #[test]
    fn cached_operator_performs_zero_estimation_mvms_after_first_batch() {
        use crate::operators::CountingOp;
        let n = 16;
        let mut rng = Pcg64::seeded(40);
        let a = Matrix::randn(n, n, &mut rng);
        let mut kmat = a.matmul(&a.transpose());
        for i in 0..n {
            kmat[(i, i)] += n as f64 * 0.5;
        }
        let counter = Arc::new(CountingOp::new(DenseOp::new(kmat)));
        let shared: SharedOp = counter.clone();
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), shared);
        let cfg = ServiceConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            workers: 1,
            ciq: CiqOptions { tol: 1e-8, ..Default::default() },
            // this test pins the *inline* first-batch estimation semantics,
            // so keep the background warmer out of the race
            warm_on_register: false,
            ..Default::default()
        };
        let svc = SamplingService::start(cfg, ops);
        let send_round = |rng: &mut Pcg64| {
            let tickets: Vec<Ticket> = (0..4)
                .map(|_| {
                    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                    svc.submit("k", ReqKind::Whiten, b)
                })
                .collect();
            for t in tickets {
                t.wait().unwrap();
            }
        };
        send_round(&mut rng);
        let after_first = counter.matvec_count();
        assert!(after_first > 0, "first batch must run Lanczos estimation");
        send_round(&mut rng);
        send_round(&mut rng);
        assert_eq!(
            counter.matvec_count(),
            after_first,
            "batches against a cached operator must perform zero estimation MVMs"
        );
        let m = svc.metrics();
        assert_eq!(m.cache_misses.load(Ordering::Relaxed), 1);
        assert!(m.cache_hits.load(Ordering::Relaxed) >= 2);
        assert!(m.saved_mvms.load(Ordering::Relaxed) > 0);
        assert!(m.column_work.load(Ordering::Relaxed) > 0);
        svc.shutdown();
    }

    #[test]
    fn replaced_operator_reestimates_bounds() {
        use crate::operators::CountingOp;
        let n = 16;
        let mut rng = Pcg64::seeded(50);
        let mk = |scale: f64, rng: &mut Pcg64| {
            let a = Matrix::randn(n, n, rng);
            let mut k = a.matmul(&a.transpose());
            for i in 0..n {
                k[(i, i)] += n as f64 * scale;
            }
            Arc::new(CountingOp::new(DenseOp::new(k)))
        };
        let old_op = mk(0.5, &mut rng);
        let new_op = mk(4.0, &mut rng); // different spectrum → different bounds
        let mut ops = HashMap::new();
        let shared_old: SharedOp = old_op.clone();
        ops.insert("k".to_string(), shared_old);
        let cfg = ServiceConfig {
            workers: 1,
            ciq: CiqOptions { tol: 1e-6, ..Default::default() },
            // deterministic miss accounting: estimation must happen inline
            warm_on_register: false,
            ..Default::default()
        };
        let svc = SamplingService::start(cfg, ops);
        let rhs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        svc.submit("k", ReqKind::Whiten, rhs.clone()).wait().unwrap();
        let old_after_first = old_op.matvec_count();
        assert!(old_after_first > 0, "first batch must run Lanczos estimation");

        let shared_new: SharedOp = new_op.clone();
        svc.replace_operator("k", shared_new);
        svc.submit("k", ReqKind::Whiten, rhs.clone()).wait().unwrap();
        assert!(
            new_op.matvec_count() > 0,
            "replaced operator must re-estimate its spectral bounds (stale cache would mean zero MVMs)"
        );
        assert_eq!(
            old_op.matvec_count(),
            old_after_first,
            "old operator must not be touched after replacement"
        );
        assert_eq!(svc.metrics().cache_misses.load(Ordering::Relaxed), 2, "one miss per operator version");
        assert_eq!(svc.metrics().operator_replacements.load(Ordering::Relaxed), 1);

        // replacement is also first-time registration
        let extra = mk(1.0, &mut rng);
        let shared_extra: SharedOp = extra.clone();
        svc.register_operator("k2", shared_extra);
        svc.submit("k2", ReqKind::Whiten, rhs).wait().unwrap();
        assert!(extra.matvec_count() > 0);

        // deregistration makes the name unknown again
        assert!(svc.deregister_operator("k2"));
        assert!(!svc.deregister_operator("k2"));
        let r = svc.submit("k2", ReqKind::Whiten, vec![0.0; n]).wait();
        assert!(r.is_err(), "deregistered operator must reject requests");
        svc.shutdown();
    }

    #[test]
    fn warmed_operator_first_batch_performs_zero_inline_estimation_mvms() {
        use crate::operators::CountingOp;
        let n = 16;
        let mut rng = Pcg64::seeded(60);
        let a = Matrix::randn(n, n, &mut rng);
        let mut kmat = a.matmul(&a.transpose());
        for i in 0..n {
            kmat[(i, i)] += n as f64 * 0.5;
        }
        let counter = Arc::new(CountingOp::new(DenseOp::new(kmat)));
        let shared: SharedOp = counter.clone();
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), shared);
        let cfg = ServiceConfig {
            workers: 1,
            ciq: CiqOptions { tol: 1e-8, ..Default::default() },
            ..Default::default() // warm_on_register: true
        };
        let svc = SamplingService::start(cfg, ops);
        // wait on the warmer's completion signal, not on a sleep guess
        let t0 = Instant::now();
        while svc.metrics().warmed_operators.load(Ordering::Relaxed) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "warmer never completed");
            std::thread::sleep(Duration::from_millis(2));
        }
        let warm_cost = counter.matvec_count();
        assert!(warm_cost > 0, "warming must run the Lanczos estimation");
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        svc.submit("k", ReqKind::Whiten, b).wait().unwrap();
        assert_eq!(
            counter.matvec_count(),
            warm_cost,
            "a warmed operator's first batch must perform zero inline estimation MVMs"
        );
        let m = svc.metrics();
        assert_eq!(m.cache_misses.load(Ordering::Relaxed), 0, "first batch recorded a miss");
        assert!(m.cache_hits.load(Ordering::Relaxed) >= 1);
        assert!(m.saved_mvms.load(Ordering::Relaxed) >= warm_cost);
        svc.shutdown();
    }

    #[test]
    fn adaptive_ceiling_backs_off_under_slow_flushes_and_prunes_on_deregister() {
        let n = 16;
        let (op, _) = make_op(n, 61);
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), op);
        let cfg = ServiceConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            workers: 1,
            ciq: CiqOptions { tol: 1e-10, ..Default::default() },
            // an impossible target: every flush overshoots, so the ceiling
            // must walk 8 → 4 → 2 and clamp at the floor
            adaptive: Some(AdaptiveBatchConfig {
                target_flush_latency: Duration::from_nanos(1),
                min_batch: 2,
            }),
            ..Default::default()
        };
        let svc = SamplingService::start(cfg, ops);
        let mut rng = Pcg64::seeded(62);
        for _ in 0..4 {
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            svc.submit("k", ReqKind::Whiten, b).wait().unwrap();
        }
        assert_eq!(
            svc.metrics().batch_ceiling("k/Whiten"),
            Some(2),
            "AIMD ceiling did not clamp to the floor under sustained overshoot"
        );
        assert_eq!(svc.metrics().batch_ceilings().len(), 1);
        // deregistration prunes the shard's telemetry (depths + ceilings)
        assert!(svc.deregister_operator("k"));
        assert!(svc.metrics().batch_ceiling("k/Whiten").is_none());
        assert!(svc.metrics().shard_depths().is_empty());
        svc.shutdown();
    }

    #[test]
    fn solve_errors_propagate_original_kind() {
        // q_points = 0 makes quadrature construction fail with Invalid; the
        // old path rewrapped every solve failure as Numerical.
        let (op, _) = make_op(8, 13);
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), op);
        let cfg = ServiceConfig {
            ciq: CiqOptions { q_points: 0, ..Default::default() },
            ..Default::default()
        };
        let svc = SamplingService::start(cfg, ops);
        let r = svc.submit("k", ReqKind::Whiten, vec![1.0; 8]).wait();
        match r {
            Err(crate::Error::Invalid(_)) => {}
            other => panic!("expected the original Invalid error to propagate, got {other:?}"),
        }
        assert_eq!(svc.metrics().failed.load(Ordering::Relaxed), 1);
        svc.shutdown();
    }

    #[test]
    fn concurrent_requests_all_answered_and_batched() {
        let n = 16;
        let (op, k) = make_op(n, 4);
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), op);
        let cfg = ServiceConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            workers: 2,
            ciq: CiqOptions { tol: 1e-9, ..Default::default() },
            ..Default::default()
        };
        let svc = SamplingService::start(cfg, ops);
        let mut rng = Pcg64::seeded(5);
        let reqs: Vec<Vec<f64>> = (0..20).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let tickets: Vec<Ticket> =
            reqs.iter().map(|b| svc.submit("k", ReqKind::Whiten, b.clone())).collect();
        // compare each against the solo exact computation
        let exact_map = crate::linalg::eigen::spd_inv_sqrt(&k).unwrap();
        for (t, b) in tickets.into_iter().zip(&reqs) {
            let got = t.wait().unwrap();
            let exact = exact_map.matvec(b);
            assert!(rel_err(&got, &exact) < 1e-5);
        }
        assert_eq!(svc.metrics().completed.load(Ordering::Relaxed), 20);
        assert!(svc.metrics().max_batch_size() > 1, "batching never kicked in");
        svc.shutdown();
    }
}

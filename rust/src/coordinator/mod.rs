//! The L3 coordinator: a batching **GP sampling service**.
//!
//! A production deployment of this paper looks like a service that answers
//! `K^{1/2} b` (sampling) and `K^{-1/2} b` (whitening) requests against a set
//! of registered covariance operators. The coordinator:
//!
//! * accepts requests over an MPSC channel (each carries its own one-shot
//!   response channel),
//! * **dynamically batches** requests that target the same `(operator, kind)`
//!   pair — up to `max_batch` RHS or `max_wait` of queueing delay — because
//!   msMINRES shares its per-iteration MVMs across a whole batch
//!   ([`crate::krylov::msminres::msminres_block`]), the marginal cost of an
//!   extra RHS is far below a solo solve (this is the knob Fig. 2 mid/right
//!   sweeps),
//! * executes batches on a worker pool sized to the machine,
//! * records per-request latency and batch-size metrics.

pub mod metrics;

pub use metrics::Metrics;

use crate::ciq::{Ciq, CiqOptions};
use crate::linalg::Matrix;
use crate::operators::LinearOp;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// What the client wants computed.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ReqKind {
    /// `K^{1/2} b` — drawing a sample with covariance `K` from white noise.
    Sample,
    /// `K^{-1/2} b` — whitening `b` against `K`.
    Whiten,
}

/// A shared covariance operator registered with the service.
pub type SharedOp = Arc<dyn LinearOp + Send + Sync>;

/// One request.
struct Request {
    op_name: String,
    kind: ReqKind,
    rhs: Vec<f64>,
    enqueued: Instant,
    respond: Sender<crate::Result<Vec<f64>>>,
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Max RHS per batch.
    pub max_batch: usize,
    /// Max time a request may wait for batch-mates.
    pub max_wait: Duration,
    /// Worker threads executing batches.
    pub workers: usize,
    /// CIQ solver options.
    pub ciq: CiqOptions,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            workers: 2,
            ciq: CiqOptions::default(),
        }
    }
}

/// Handle to a running sampling service.
pub struct SamplingService {
    tx: Option<Sender<Request>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    metrics: Arc<Metrics>,
}

/// A pending response.
pub struct Ticket {
    rx: Receiver<crate::Result<Vec<f64>>>,
}

impl Ticket {
    /// Block until the result arrives.
    pub fn wait(self) -> crate::Result<Vec<f64>> {
        self.rx
            .recv()
            .unwrap_or_else(|_| Err(crate::Error::Runtime("service dropped request".into())))
    }
}

struct Batch {
    op_name: String,
    kind: ReqKind,
    requests: Vec<Request>,
}

impl SamplingService {
    /// Start the service with a set of named operators.
    pub fn start(config: ServiceConfig, ops: HashMap<String, SharedOp>) -> SamplingService {
        let (tx, rx) = mpsc::channel::<Request>();
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let dispatcher = std::thread::spawn(move || dispatcher_loop(config, ops, rx, m2));
        SamplingService { tx: Some(tx), dispatcher: Some(dispatcher), metrics }
    }

    /// Submit a request; returns a [`Ticket`] to wait on.
    pub fn submit(&self, op_name: &str, kind: ReqKind, rhs: Vec<f64>) -> Ticket {
        let (rtx, rrx) = mpsc::channel();
        let req = Request {
            op_name: op_name.to_string(),
            kind,
            rhs,
            enqueued: Instant::now(),
            respond: rtx,
        };
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        // if the dispatcher is gone the Ticket will report the failure
        let _ = self.tx.as_ref().unwrap().send(req);
        Ticket { rx: rrx }
    }

    /// Service metrics.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Graceful shutdown: drains in-flight requests.
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for SamplingService {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

fn dispatcher_loop(
    config: ServiceConfig,
    ops: HashMap<String, SharedOp>,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
) {
    // worker pool
    let (btx, brx) = mpsc::channel::<Batch>();
    let brx = Arc::new(std::sync::Mutex::new(brx));
    let ops = Arc::new(ops);
    let stop = Arc::new(AtomicBool::new(false));
    let mut workers = Vec::new();
    for _ in 0..config.workers.max(1) {
        let brx = brx.clone();
        let ops = ops.clone();
        let metrics = metrics.clone();
        let ciq_opts = config.ciq.clone();
        let stop = stop.clone();
        workers.push(std::thread::spawn(move || loop {
            let batch = {
                let guard = brx.lock().unwrap();
                match guard.recv_timeout(Duration::from_millis(20)) {
                    Ok(b) => b,
                    Err(RecvTimeoutError::Timeout) => {
                        if stop.load(Ordering::Acquire) {
                            return;
                        }
                        continue;
                    }
                    Err(RecvTimeoutError::Disconnected) => return,
                }
            };
            execute_batch(&ops, &ciq_opts, batch, &metrics);
        }));
    }

    // batching loop
    let mut pending: HashMap<(String, ReqKind), Vec<Request>> = HashMap::new();
    loop {
        let timeout = if pending.is_empty() { Duration::from_millis(50) } else { config.max_wait };
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                let key = (req.op_name.clone(), req.kind);
                let queue = pending.entry(key.clone()).or_default();
                queue.push(req);
                if queue.len() >= config.max_batch {
                    let requests = pending.remove(&key).unwrap();
                    metrics.record_batch(requests.len());
                    let _ = btx.send(Batch { op_name: key.0, kind: key.1, requests });
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                // flush everything that waited long enough (or anything, on idle)
                let keys: Vec<_> = pending.keys().cloned().collect();
                for key in keys {
                    let flush = pending
                        .get(&key)
                        .map(|q| {
                            q.first()
                                .map(|r| r.enqueued.elapsed() >= config.max_wait)
                                .unwrap_or(false)
                        })
                        .unwrap_or(false);
                    if flush {
                        let requests = pending.remove(&key).unwrap();
                        metrics.record_batch(requests.len());
                        let _ = btx.send(Batch { op_name: key.0, kind: key.1, requests });
                    }
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                // drain remaining
                for ((op_name, kind), requests) in pending.drain() {
                    metrics.record_batch(requests.len());
                    let _ = btx.send(Batch { op_name, kind, requests });
                }
                break;
            }
        }
    }
    drop(btx);
    stop.store(true, Ordering::Release);
    for w in workers {
        let _ = w.join();
    }
}

fn execute_batch(
    ops: &HashMap<String, SharedOp>,
    ciq_opts: &CiqOptions,
    batch: Batch,
    metrics: &Metrics,
) {
    let op = match ops.get(&batch.op_name) {
        Some(op) => op.clone(),
        None => {
            for req in batch.requests {
                let _ = req
                    .respond
                    .send(Err(crate::Error::Invalid(format!("unknown operator '{}'", batch.op_name))));
            }
            return;
        }
    };
    let n = op.size();
    // validate sizes
    let mut valid = Vec::new();
    for req in batch.requests {
        if req.rhs.len() != n {
            let _ = req.respond.send(Err(crate::Error::Shape(format!(
                "rhs len {} != operator size {n}",
                req.rhs.len()
            ))));
        } else {
            valid.push(req);
        }
    }
    if valid.is_empty() {
        return;
    }
    let r = valid.len();
    let mut b = Matrix::zeros(n, r);
    for (j, req) in valid.iter().enumerate() {
        for i in 0..n {
            b[(i, j)] = req.rhs[i];
        }
    }
    let solver = Ciq::new(ciq_opts.clone());
    let result = match batch.kind {
        ReqKind::Sample => solver.sqrt_mvm_block(op.as_ref(), &b),
        ReqKind::Whiten => solver.invsqrt_mvm_block(op.as_ref(), &b),
    };
    match result {
        Ok((out, iters)) => {
            metrics.record_iters(&iters);
            for (j, req) in valid.into_iter().enumerate() {
                let col = out.col(j);
                metrics.record_latency(req.enqueued.elapsed());
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                let _ = req.respond.send(Ok(col));
            }
        }
        Err(e) => {
            let msg = format!("batch solve failed: {e}");
            for req in valid {
                metrics.failed.fetch_add(1, Ordering::Relaxed);
                let _ = req.respond.send(Err(crate::Error::Numerical(msg.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operators::DenseOp;
    use crate::rng::Pcg64;
    use crate::util::rel_err;

    fn make_op(n: usize, seed: u64) -> (SharedOp, Matrix) {
        let mut rng = Pcg64::seeded(seed);
        let a = Matrix::randn(n, n, &mut rng);
        let mut k = a.matmul(&a.transpose());
        for i in 0..n {
            k[(i, i)] += n as f64 * 0.5;
        }
        (Arc::new(DenseOp::new(k.clone())), k)
    }

    #[test]
    fn roundtrip_whiten_then_sample() {
        let n = 24;
        let (op, _k) = make_op(n, 1);
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), op);
        let cfg = ServiceConfig {
            ciq: CiqOptions { tol: 1e-9, ..Default::default() },
            ..Default::default()
        };
        let svc = SamplingService::start(cfg, ops);
        let mut rng = Pcg64::seeded(2);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let w = svc.submit("k", ReqKind::Whiten, b.clone()).wait().unwrap();
        let s = svc.submit("k", ReqKind::Sample, w).wait().unwrap();
        assert!(rel_err(&s, &b) < 1e-4, "whiten→sample roundtrip");
        svc.shutdown();
    }

    #[test]
    fn unknown_operator_errors() {
        let (op, _) = make_op(8, 3);
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), op);
        let svc = SamplingService::start(ServiceConfig::default(), ops);
        let r = svc.submit("nope", ReqKind::Sample, vec![0.0; 8]).wait();
        assert!(r.is_err());
        let r2 = svc.submit("k", ReqKind::Sample, vec![0.0; 3]).wait();
        assert!(r2.is_err());
        svc.shutdown();
    }

    #[test]
    fn concurrent_requests_all_answered_and_batched() {
        let n = 16;
        let (op, k) = make_op(n, 4);
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), op);
        let cfg = ServiceConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            workers: 2,
            ciq: CiqOptions { tol: 1e-9, ..Default::default() },
        };
        let svc = SamplingService::start(cfg, ops);
        let mut rng = Pcg64::seeded(5);
        let reqs: Vec<Vec<f64>> = (0..20).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let tickets: Vec<Ticket> =
            reqs.iter().map(|b| svc.submit("k", ReqKind::Whiten, b.clone())).collect();
        // compare each against the solo exact computation
        let exact_map = crate::linalg::eigen::spd_inv_sqrt(&k).unwrap();
        for (t, b) in tickets.into_iter().zip(&reqs) {
            let got = t.wait().unwrap();
            let exact = exact_map.matvec(b);
            assert!(rel_err(&got, &exact) < 1e-5);
        }
        assert_eq!(svc.metrics().completed.load(Ordering::Relaxed), 20);
        assert!(svc.metrics().max_batch_size() > 1, "batching never kicked in");
        svc.shutdown();
    }
}

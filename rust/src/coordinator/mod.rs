//! The L3 coordinator: a **cache-aware, sharded** batching GP sampling
//! service.
//!
//! A production deployment of this paper looks like a service that answers
//! `K^{1/2} b` (sampling) and `K^{-1/2} b` (whitening) requests against a set
//! of registered covariance operators. The coordinator:
//!
//! * accepts requests over an MPSC channel (each carries its own one-shot
//!   response channel),
//! * routes each request to a **shard** keyed by `(operator, kind)` and
//!   **dynamically batches** within the shard — up to `max_batch` RHS or
//!   `max_wait` of queueing delay — because msMINRES shares its per-iteration
//!   MVMs across a whole batch
//!   ([`crate::krylov::msminres::msminres_block`]), the marginal cost of an
//!   extra RHS is far below a solo solve (this is the knob Fig. 2 mid/right
//!   sweeps),
//! * executes batches on a worker pool sized to the machine,
//! * records per-request latency, batch-size, per-shard queue-depth, and
//!   cache-economics metrics.
//!
//! ## Shard flushing is deadline-driven
//!
//! The dispatcher's `recv` timeout is computed from the **oldest pending
//! request's flush deadline** across all shards (not a fixed `max_wait` after
//! the most recent arrival), and expired shards are flushed after *every*
//! received request. This matters under steady load: a trickle of requests
//! arriving faster than `max_wait` used to keep the receive loop on its `Ok`
//! path forever, so a sub-`max_batch` queue was never flushed until the
//! trickle stopped (flush starvation). Now a request waits at most
//! `max_wait` (plus solve time) regardless of arrival pattern.
//!
//! ## Per-operator spectral caches
//!
//! Registered operators are immutable for the life of the service, so their
//! spectral bounds — and the CIQ quadrature rule derived from them — are
//! computed by Lanczos **once**, on the first batch that touches the
//! operator, and reused by every batch after that
//! ([`crate::ciq::SolverCache`]). Each cache hit is credited with the
//! estimation MVMs the cold batch actually spent (measured, not assumed);
//! [`Metrics::saved_mvms`] totals the savings from live traffic. The cache is guarded by a per-operator mutex so
//! concurrent first batches on one operator never duplicate the estimation.
//!
//! ## Operator replacement versions the cache
//!
//! [`SamplingService::replace_operator`] (and
//! [`SamplingService::register_operator`]) installs a **fresh**
//! operator entry whose spectral cache starts empty, so a re-registered
//! operator can never be served stale Lanczos bounds or a stale quadrature
//! rule. Batches already in flight hold an `Arc` to the *old* entry and
//! finish against the consistent (old operator, old cache) pair; the old
//! entry — cache included — is dropped when the last of them completes.

pub mod metrics;

pub use metrics::Metrics;

use crate::ciq::{Ciq, CiqOptions, SolverCache};
use crate::linalg::Matrix;
use crate::operators::LinearOp;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// What the client wants computed.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ReqKind {
    /// `K^{1/2} b` — drawing a sample with covariance `K` from white noise.
    Sample,
    /// `K^{-1/2} b` — whitening `b` against `K`.
    Whiten,
}

/// A shared covariance operator registered with the service.
pub type SharedOp = Arc<dyn LinearOp + Send + Sync>;

/// A registered operator plus its lazily-filled spectral cache.
///
/// The cache is a `Mutex<Option<…>>` rather than a `OnceLock` deliberately:
/// holding the lock across the Lanczos estimation makes a concurrent second
/// batch on the same cold operator *wait* for the first estimation instead of
/// redundantly re-running it.
struct OpEntry {
    op: SharedOp,
    /// `(cache, MVMs the one-time estimation actually spent)` — hits credit
    /// exactly what the miss paid, even when Lanczos broke out early.
    spectral: Mutex<Option<(Arc<SolverCache>, u64)>>,
}

impl OpEntry {
    fn fresh(op: SharedOp) -> Arc<OpEntry> {
        Arc::new(OpEntry { op, spectral: Mutex::new(None) })
    }
}

/// The live operator registry, shared by the service handle, the
/// dispatcher, and the batch workers. Entries are swapped whole on
/// replacement, never mutated in place.
type OpMap = Arc<RwLock<HashMap<String, Arc<OpEntry>>>>;

/// Shard key: requests are queued and batched per `(operator, kind)`.
type ShardKey = (String, ReqKind);

fn shard_label(op_name: &str, kind: ReqKind) -> String {
    format!("{op_name}/{kind:?}")
}

/// One request.
struct Request {
    op_name: String,
    kind: ReqKind,
    rhs: Vec<f64>,
    enqueued: Instant,
    respond: Sender<crate::Result<Vec<f64>>>,
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Max RHS per batch.
    pub max_batch: usize,
    /// Max time a request may wait for batch-mates.
    pub max_wait: Duration,
    /// Worker threads executing batches.
    pub workers: usize,
    /// CIQ solver options.
    pub ciq: CiqOptions,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            workers: 2,
            ciq: CiqOptions::default(),
        }
    }
}

/// Handle to a running sampling service.
pub struct SamplingService {
    tx: Option<Sender<Request>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    metrics: Arc<Metrics>,
    ops: OpMap,
}

/// A pending response.
pub struct Ticket {
    rx: Receiver<crate::Result<Vec<f64>>>,
}

impl Ticket {
    /// Block until the result arrives.
    pub fn wait(self) -> crate::Result<Vec<f64>> {
        self.rx
            .recv()
            .unwrap_or_else(|_| Err(crate::Error::Runtime("service dropped request".into())))
    }
}

struct Batch {
    op_name: String,
    kind: ReqKind,
    requests: Vec<Request>,
}

impl SamplingService {
    /// Start the service with a set of named operators.
    pub fn start(config: ServiceConfig, ops: HashMap<String, SharedOp>) -> SamplingService {
        let entries: HashMap<String, Arc<OpEntry>> =
            ops.into_iter().map(|(name, op)| (name, OpEntry::fresh(op))).collect();
        let registry: OpMap = Arc::new(RwLock::new(entries));
        let (tx, rx) = mpsc::channel::<Request>();
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let r2 = registry.clone();
        let dispatcher = std::thread::spawn(move || dispatcher_loop(config, r2, rx, m2));
        SamplingService { tx: Some(tx), dispatcher: Some(dispatcher), metrics, ops: registry }
    }

    /// Register a new operator under `name`, or atomically **replace** an
    /// existing one. Replacement installs a fresh entry whose spectral cache
    /// starts empty — the next batch on `name` re-runs Lanczos estimation,
    /// so stale bounds/quadrature from the old operator can never serve the
    /// new one (the versioning contract in the module docs).
    pub fn replace_operator(&self, name: &str, op: SharedOp) {
        self.metrics.operator_replacements.fetch_add(1, Ordering::Relaxed);
        self.ops.write().unwrap().insert(name.to_string(), OpEntry::fresh(op));
    }

    /// Alias of [`Self::replace_operator`] for first-time registration after
    /// startup.
    pub fn register_operator(&self, name: &str, op: SharedOp) {
        self.replace_operator(name, op);
    }

    /// Remove an operator (and its spectral cache); in-flight batches
    /// complete against the entry they already hold. Returns whether the
    /// name was registered.
    pub fn deregister_operator(&self, name: &str) -> bool {
        self.ops.write().unwrap().remove(name).is_some()
    }

    /// Submit a request; returns a [`Ticket`] to wait on.
    pub fn submit(&self, op_name: &str, kind: ReqKind, rhs: Vec<f64>) -> Ticket {
        let (rtx, rrx) = mpsc::channel();
        let req = Request {
            op_name: op_name.to_string(),
            kind,
            rhs,
            enqueued: Instant::now(),
            respond: rtx,
        };
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        // if the dispatcher is gone the Ticket will report the failure
        let _ = self.tx.as_ref().unwrap().send(req);
        Ticket { rx: rrx }
    }

    /// Service metrics.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Graceful shutdown: drains in-flight requests.
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for SamplingService {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

/// Dispatcher-side shard: pending requests plus the precomputed metrics
/// label (built once per shard, not once per arrival).
struct Shard {
    label: String,
    requests: Vec<Request>,
}

/// Send one shard's queue off as a batch.
fn flush_shard(
    key: &ShardKey,
    shards: &mut HashMap<ShardKey, Shard>,
    btx: &Sender<Batch>,
    metrics: &Metrics,
) {
    if let Some(shard) = shards.remove(key) {
        if shard.requests.is_empty() {
            return;
        }
        metrics.record_batch(shard.requests.len());
        metrics.record_shard_depth(&shard.label, 0);
        let _ = btx.send(Batch { op_name: key.0.clone(), kind: key.1, requests: shard.requests });
    }
}

/// Flush every shard whose oldest request has waited at least `max_wait`,
/// and return the earliest flush deadline still pending — the single source
/// of truth for the dispatcher's next recv timeout.
fn flush_expired(
    shards: &mut HashMap<ShardKey, Shard>,
    max_wait: Duration,
    btx: &Sender<Batch>,
    metrics: &Metrics,
) -> Option<Instant> {
    let now = Instant::now();
    let expired: Vec<ShardKey> = shards
        .iter()
        .filter(|(_, s)| s.requests.first().map(|r| r.enqueued + max_wait <= now).unwrap_or(false))
        .map(|(k, _)| k.clone())
        .collect();
    for key in expired {
        flush_shard(&key, shards, btx, metrics);
    }
    shards.values().filter_map(|s| s.requests.first().map(|r| r.enqueued + max_wait)).min()
}

fn dispatcher_loop(
    config: ServiceConfig,
    ops: OpMap,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
) {
    // worker pool
    let (btx, brx) = mpsc::channel::<Batch>();
    let brx = Arc::new(Mutex::new(brx));
    let stop = Arc::new(AtomicBool::new(false));
    let mut workers = Vec::new();
    for _ in 0..config.workers.max(1) {
        let brx = brx.clone();
        let ops = ops.clone();
        let metrics = metrics.clone();
        let ciq_opts = config.ciq.clone();
        let stop = stop.clone();
        workers.push(std::thread::spawn(move || loop {
            let batch = {
                let guard = brx.lock().unwrap();
                match guard.recv_timeout(Duration::from_millis(20)) {
                    Ok(b) => b,
                    Err(RecvTimeoutError::Timeout) => {
                        if stop.load(Ordering::Acquire) {
                            return;
                        }
                        continue;
                    }
                    Err(RecvTimeoutError::Disconnected) => return,
                }
            };
            execute_batch(&ops, &ciq_opts, batch, &metrics);
        }));
    }

    // sharded batching loop: one queue per (operator, kind)
    let idle_poll = Duration::from_millis(50);
    let mut shards: HashMap<ShardKey, Shard> = HashMap::new();
    // Deadline-aware receive: wake when the *oldest pending* request's flush
    // deadline expires, never a fixed max_wait after the most recent arrival.
    let mut next_deadline: Option<Instant> = None;
    loop {
        let timeout = next_deadline
            .map(|deadline| deadline.saturating_duration_since(Instant::now()))
            .unwrap_or(idle_poll);
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                if !ops.read().unwrap().contains_key(&req.op_name) {
                    // Rejected up front: no shard is created, so
                    // client-controlled names cannot grow the shard map or
                    // its metrics without bound.
                    metrics.failed.fetch_add(1, Ordering::Relaxed);
                    let _ = req.respond.send(Err(crate::Error::Invalid(format!(
                        "unknown operator '{}'",
                        req.op_name
                    ))));
                } else {
                    let key = (req.op_name.clone(), req.kind);
                    let shard = shards.entry(key.clone()).or_insert_with(|| Shard {
                        label: shard_label(&key.0, key.1),
                        requests: Vec::new(),
                    });
                    shard.requests.push(req);
                    let depth = shard.requests.len();
                    metrics.record_shard_depth(&shard.label, depth);
                    if depth >= config.max_batch {
                        flush_shard(&key, &mut shards, &btx, &metrics);
                    }
                }
                // Deadlines are re-checked after *every* arrival — a steady
                // trickle faster than max_wait can no longer starve a
                // sub-max_batch shard of its flush — but the O(shards) scan
                // only runs once the known earliest deadline has passed (a
                // new arrival's own deadline, now + max_wait, is never the
                // one expiring; a stale-early deadline from a max_batch flush
                // just wakes the loop once ahead of time and self-corrects).
                match next_deadline {
                    Some(deadline) if deadline > Instant::now() => {}
                    _ => {
                        next_deadline =
                            flush_expired(&mut shards, config.max_wait, &btx, &metrics);
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                next_deadline = flush_expired(&mut shards, config.max_wait, &btx, &metrics);
            }
            Err(RecvTimeoutError::Disconnected) => {
                // drain remaining
                let keys: Vec<ShardKey> = shards.keys().cloned().collect();
                for key in keys {
                    flush_shard(&key, &mut shards, &btx, &metrics);
                }
                break;
            }
        }
    }
    drop(btx);
    stop.store(true, Ordering::Release);
    for w in workers {
        let _ = w.join();
    }
}

/// Fetch (or compute-and-fill, on first contact) an operator's spectral
/// cache. Holding the per-operator lock across the estimation means
/// concurrent cold batches wait instead of duplicating the Lanczos MVMs.
fn cached_spectral(
    entry: &OpEntry,
    solver: &Ciq,
    metrics: &Metrics,
) -> crate::Result<Arc<SolverCache>> {
    let mut guard = entry.spectral.lock().unwrap();
    if let Some((cache, estimation_mvms)) = guard.as_ref() {
        metrics.record_cache_hit(*estimation_mvms);
        return Ok(cache.clone());
    }
    // A miss means "estimation ran", so record it before the fallible build —
    // repeated estimation on a failing operator stays visible in telemetry.
    metrics.record_cache_miss();
    // count what the estimation actually spends (Lanczos may break out early
    // on an invariant subspace) so hits credit the true savings
    let counting = crate::operators::CountingOp::new(entry.op.as_ref());
    let cache = Arc::new(solver.solver_cache(&counting)?);
    let estimation_mvms = counting.matvec_count();
    *guard = Some((cache.clone(), estimation_mvms));
    Ok(cache)
}

fn execute_batch(ops: &OpMap, ciq_opts: &CiqOptions, batch: Batch, metrics: &Metrics) {
    // Pin this batch's (operator, cache) pair up front: a concurrent
    // replace_operator swaps the map entry but cannot mix versions here.
    let entry = match ops.read().unwrap().get(&batch.op_name).cloned() {
        Some(entry) => entry,
        None => {
            for req in batch.requests {
                metrics.failed.fetch_add(1, Ordering::Relaxed);
                let _ = req
                    .respond
                    .send(Err(crate::Error::Invalid(format!("unknown operator '{}'", batch.op_name))));
            }
            return;
        }
    };
    let op = entry.op.clone();
    let n = op.size();
    // validate sizes
    let mut valid = Vec::new();
    for req in batch.requests {
        if req.rhs.len() != n {
            metrics.failed.fetch_add(1, Ordering::Relaxed);
            let _ = req.respond.send(Err(crate::Error::Shape(format!(
                "rhs len {} != operator size {n}",
                req.rhs.len()
            ))));
        } else {
            valid.push(req);
        }
    }
    if valid.is_empty() {
        return;
    }
    let r = valid.len();
    let mut b = Matrix::zeros(n, r);
    for (j, req) in valid.iter().enumerate() {
        for i in 0..n {
            b[(i, j)] = req.rhs[i];
        }
    }
    let solver = Ciq::new(ciq_opts.clone());
    let result = cached_spectral(&entry, &solver, metrics).and_then(|cache| match batch.kind {
        ReqKind::Sample => solver.sqrt_mvm_block_with_bounds(op.as_ref(), &b, Some(&*cache)),
        ReqKind::Whiten => solver.invsqrt_mvm_block_with_bounds(op.as_ref(), &b, Some(&*cache)),
    });
    match result {
        Ok(res) => {
            metrics.record_iters(&res.col_iterations);
            // compaction telemetry: matmat columns paid vs the uncompacted
            // `iterations × columns` cost
            let full = res.col_iterations.iter().copied().max().unwrap_or(0) * r;
            metrics.record_column_work(res.column_work as u64, full as u64);
            for (j, req) in valid.into_iter().enumerate() {
                let col = res.solution.col(j);
                metrics.record_latency(req.enqueued.elapsed());
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                let _ = req.respond.send(Ok(col));
            }
        }
        Err(e) => {
            // propagate the underlying error kind per request (no rewrap)
            for req in valid {
                metrics.failed.fetch_add(1, Ordering::Relaxed);
                let _ = req.respond.send(Err(e.clone()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operators::DenseOp;
    use crate::rng::Pcg64;
    use crate::util::rel_err;

    fn make_op(n: usize, seed: u64) -> (SharedOp, Matrix) {
        let mut rng = Pcg64::seeded(seed);
        let a = Matrix::randn(n, n, &mut rng);
        let mut k = a.matmul(&a.transpose());
        for i in 0..n {
            k[(i, i)] += n as f64 * 0.5;
        }
        (Arc::new(DenseOp::new(k.clone())), k)
    }

    #[test]
    fn roundtrip_whiten_then_sample() {
        let n = 24;
        let (op, _k) = make_op(n, 1);
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), op);
        let cfg = ServiceConfig {
            ciq: CiqOptions { tol: 1e-9, ..Default::default() },
            ..Default::default()
        };
        let svc = SamplingService::start(cfg, ops);
        let mut rng = Pcg64::seeded(2);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let w = svc.submit("k", ReqKind::Whiten, b.clone()).wait().unwrap();
        let s = svc.submit("k", ReqKind::Sample, w).wait().unwrap();
        assert!(rel_err(&s, &b) < 1e-4, "whiten→sample roundtrip");
        svc.shutdown();
    }

    #[test]
    fn unknown_operator_errors() {
        let (op, _) = make_op(8, 3);
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), op);
        let svc = SamplingService::start(ServiceConfig::default(), ops);
        let r = svc.submit("nope", ReqKind::Sample, vec![0.0; 8]).wait();
        assert!(r.is_err());
        let r2 = svc.submit("k", ReqKind::Sample, vec![0.0; 3]).wait();
        assert!(r2.is_err());
        svc.shutdown();
    }

    #[test]
    fn cached_operator_performs_zero_estimation_mvms_after_first_batch() {
        use crate::operators::CountingOp;
        let n = 16;
        let mut rng = Pcg64::seeded(40);
        let a = Matrix::randn(n, n, &mut rng);
        let mut kmat = a.matmul(&a.transpose());
        for i in 0..n {
            kmat[(i, i)] += n as f64 * 0.5;
        }
        let counter = Arc::new(CountingOp::new(DenseOp::new(kmat)));
        let shared: SharedOp = counter.clone();
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), shared);
        let cfg = ServiceConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            workers: 1,
            ciq: CiqOptions { tol: 1e-8, ..Default::default() },
        };
        let svc = SamplingService::start(cfg, ops);
        let send_round = |rng: &mut Pcg64| {
            let tickets: Vec<Ticket> = (0..4)
                .map(|_| {
                    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                    svc.submit("k", ReqKind::Whiten, b)
                })
                .collect();
            for t in tickets {
                t.wait().unwrap();
            }
        };
        send_round(&mut rng);
        let after_first = counter.matvec_count();
        assert!(after_first > 0, "first batch must run Lanczos estimation");
        send_round(&mut rng);
        send_round(&mut rng);
        assert_eq!(
            counter.matvec_count(),
            after_first,
            "batches against a cached operator must perform zero estimation MVMs"
        );
        let m = svc.metrics();
        assert_eq!(m.cache_misses.load(Ordering::Relaxed), 1);
        assert!(m.cache_hits.load(Ordering::Relaxed) >= 2);
        assert!(m.saved_mvms.load(Ordering::Relaxed) > 0);
        assert!(m.column_work.load(Ordering::Relaxed) > 0);
        svc.shutdown();
    }

    #[test]
    fn replaced_operator_reestimates_bounds() {
        use crate::operators::CountingOp;
        let n = 16;
        let mut rng = Pcg64::seeded(50);
        let mk = |scale: f64, rng: &mut Pcg64| {
            let a = Matrix::randn(n, n, rng);
            let mut k = a.matmul(&a.transpose());
            for i in 0..n {
                k[(i, i)] += n as f64 * scale;
            }
            Arc::new(CountingOp::new(DenseOp::new(k)))
        };
        let old_op = mk(0.5, &mut rng);
        let new_op = mk(4.0, &mut rng); // different spectrum → different bounds
        let mut ops = HashMap::new();
        let shared_old: SharedOp = old_op.clone();
        ops.insert("k".to_string(), shared_old);
        let cfg = ServiceConfig {
            workers: 1,
            ciq: CiqOptions { tol: 1e-6, ..Default::default() },
            ..Default::default()
        };
        let svc = SamplingService::start(cfg, ops);
        let rhs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        svc.submit("k", ReqKind::Whiten, rhs.clone()).wait().unwrap();
        let old_after_first = old_op.matvec_count();
        assert!(old_after_first > 0, "first batch must run Lanczos estimation");

        let shared_new: SharedOp = new_op.clone();
        svc.replace_operator("k", shared_new);
        svc.submit("k", ReqKind::Whiten, rhs.clone()).wait().unwrap();
        assert!(
            new_op.matvec_count() > 0,
            "replaced operator must re-estimate its spectral bounds (stale cache would mean zero MVMs)"
        );
        assert_eq!(
            old_op.matvec_count(),
            old_after_first,
            "old operator must not be touched after replacement"
        );
        assert_eq!(svc.metrics().cache_misses.load(Ordering::Relaxed), 2, "one miss per operator version");
        assert_eq!(svc.metrics().operator_replacements.load(Ordering::Relaxed), 1);

        // replacement is also first-time registration
        let extra = mk(1.0, &mut rng);
        let shared_extra: SharedOp = extra.clone();
        svc.register_operator("k2", shared_extra);
        svc.submit("k2", ReqKind::Whiten, rhs).wait().unwrap();
        assert!(extra.matvec_count() > 0);

        // deregistration makes the name unknown again
        assert!(svc.deregister_operator("k2"));
        assert!(!svc.deregister_operator("k2"));
        let r = svc.submit("k2", ReqKind::Whiten, vec![0.0; n]).wait();
        assert!(r.is_err(), "deregistered operator must reject requests");
        svc.shutdown();
    }

    #[test]
    fn solve_errors_propagate_original_kind() {
        // q_points = 0 makes quadrature construction fail with Invalid; the
        // old path rewrapped every solve failure as Numerical.
        let (op, _) = make_op(8, 13);
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), op);
        let cfg = ServiceConfig {
            ciq: CiqOptions { q_points: 0, ..Default::default() },
            ..Default::default()
        };
        let svc = SamplingService::start(cfg, ops);
        let r = svc.submit("k", ReqKind::Whiten, vec![1.0; 8]).wait();
        match r {
            Err(crate::Error::Invalid(_)) => {}
            other => panic!("expected the original Invalid error to propagate, got {other:?}"),
        }
        assert_eq!(svc.metrics().failed.load(Ordering::Relaxed), 1);
        svc.shutdown();
    }

    #[test]
    fn concurrent_requests_all_answered_and_batched() {
        let n = 16;
        let (op, k) = make_op(n, 4);
        let mut ops = HashMap::new();
        ops.insert("k".to_string(), op);
        let cfg = ServiceConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            workers: 2,
            ciq: CiqOptions { tol: 1e-9, ..Default::default() },
        };
        let svc = SamplingService::start(cfg, ops);
        let mut rng = Pcg64::seeded(5);
        let reqs: Vec<Vec<f64>> = (0..20).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let tickets: Vec<Ticket> =
            reqs.iter().map(|b| svc.submit("k", ReqKind::Whiten, b.clone())).collect();
        // compare each against the solo exact computation
        let exact_map = crate::linalg::eigen::spd_inv_sqrt(&k).unwrap();
        for (t, b) in tickets.into_iter().zip(&reqs) {
            let got = t.wait().unwrap();
            let exact = exact_map.matvec(b);
            assert!(rel_err(&got, &exact) < 1e-5);
        }
        assert_eq!(svc.metrics().completed.load(Ordering::Relaxed), 20);
        assert!(svc.metrics().max_batch_size() > 1, "batching never kicked in");
        svc.shutdown();
    }
}

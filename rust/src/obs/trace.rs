//! Flight recorder: per-thread bounded ring buffers of timestamped
//! structured events, drained into a [`TraceSnapshot`] and exportable as
//! Chrome trace-event JSON (loadable in Perfetto / `chrome://tracing`).
//!
//! # Cost contract
//!
//! - **Disabled** (the default): every [`trace!`](crate::trace) site is one
//!   relaxed atomic load and a branch — no timestamp, no TLS access, no
//!   write. Bench §10 (`perf_hotpath`) asserts this stays within noise of a
//!   plain branch.
//! - **Enabled**: one clock read plus six relaxed stores and two
//!   fence/release operations into a fixed, pre-registered ring — no mutex,
//!   and no allocation after a thread's first event (registration creates
//!   the thread's ring once; `alloc_regression` covers the steady state).
//!
//! # Memory model
//!
//! Each thread owns one fixed-capacity ring (`DEFAULT_CAP` events, oldest
//! overwritten first) and is its only writer; a drain may run concurrently
//! from any thread. Every slot is published with a per-slot seqlock: the
//! writer marks the slot odd, Release-fences, writes the payload, then
//! Release-stores the even generation; the reader Acquire-loads the
//! generation, reads the payload, Acquire-fences, and re-reads the
//! generation — a mismatch or odd value means a torn slot, which is skipped,
//! never surfaced. All primitives route through `util::sync`, so under
//! `--cfg ciq_model` the same code runs inside the deterministic
//! interleaving checker (`tests/model_exec.rs`, mutation M6 validates that
//! the publish ordering is load-bearing).

use crate::util::sync::{fence, AtomicBool, AtomicU64, Mutex, Ordering};
use std::sync::Arc;

/// Events a thread's ring holds before wrapping (power of two).
pub const DEFAULT_CAP: usize = 4096;

/// Structured event kinds wired through the request path. Payload words
/// `(a, b)` per kind are documented on each variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u64)]
pub enum EventKind {
    /// Request accepted into a shard queue. `a` = request id, `b` = request
    /// kind discriminant.
    Enqueue = 1,
    /// Shard flushed because it reached its batch ceiling. `a` = batch size,
    /// `b` = id of the first request in the batch.
    FlushFull = 2,
    /// Shard flushed by its deadline timer. `a` = batch size, `b` = id of
    /// the first request in the batch.
    FlushDeadline = 3,
    /// Solve workspace checked out of the pool. `a` = batch size, `b` = 0.
    WorkspaceCheckout = 4,
    /// Solver entry (msMINRES path). `a` = right-hand-side columns,
    /// `b` = operator dimension.
    SolveStart = 5,
    /// Solver exit. `a` = max iterations across columns (the MVM count),
    /// `b` = total column-work performed.
    SolveEnd = 6,
    /// Batch served from cached dense `K^{±1/2}` factors. `a` = requests
    /// served, `b` = size-class `n`.
    DenseServe = 7,
    /// Dense tier handed requests back to the msMINRES path. `a` = requests
    /// falling back, `b` = size-class `n`.
    DenseFallback = 8,
    /// Batched Newton–Schulz factor build. `a` = operators factored,
    /// `b` = size-class `n`.
    DenseFactorBuild = 9,
    /// Background warmer picked up a context build. `a` = operator
    /// dimension.
    WarmStart = 10,
    /// Warmer finished. `a` = 1 if this warm performed the build (0: a
    /// racing batch already filled the context), `b` = operator dimension.
    WarmDone = 11,
    /// Warmer failed a context build (batch path will retry inline).
    /// `a` = operator dimension.
    WarmFail = 12,
    /// Response sent to the client. `a` = request id, `b` = end-to-end
    /// latency in µs.
    Respond = 13,
    /// One mixed-precision refinement residual check ran
    /// (`rust/DESIGN.md` §9). `a` = refinement sweeps completed when the
    /// check ran (0 = right after the inner mixed solve), `b` = the worst
    /// true f64 relative residual observed, as `f64::to_bits`.
    RefineSweep = 14,
}

impl EventKind {
    fn from_u64(v: u64) -> Option<EventKind> {
        Some(match v {
            1 => EventKind::Enqueue,
            2 => EventKind::FlushFull,
            3 => EventKind::FlushDeadline,
            4 => EventKind::WorkspaceCheckout,
            5 => EventKind::SolveStart,
            6 => EventKind::SolveEnd,
            7 => EventKind::DenseServe,
            8 => EventKind::DenseFallback,
            9 => EventKind::DenseFactorBuild,
            10 => EventKind::WarmStart,
            11 => EventKind::WarmDone,
            12 => EventKind::WarmFail,
            13 => EventKind::Respond,
            14 => EventKind::RefineSweep,
            _ => return None,
        })
    }

    /// Stable display name (Chrome trace event name).
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Enqueue => "enqueue",
            EventKind::FlushFull => "flush_full",
            EventKind::FlushDeadline => "flush_deadline",
            EventKind::WorkspaceCheckout => "ws_checkout",
            EventKind::SolveStart => "solve_start",
            EventKind::SolveEnd => "solve_end",
            EventKind::DenseServe => "dense_serve",
            EventKind::DenseFallback => "dense_fallback",
            EventKind::DenseFactorBuild => "dense_factor_build",
            EventKind::WarmStart => "warm_start",
            EventKind::WarmDone => "warm_done",
            EventKind::WarmFail => "warm_fail",
            EventKind::Respond => "respond",
            EventKind::RefineSweep => "refine_sweep",
        }
    }
}

/// One drained event: ring owner, global write index within that ring,
/// epoch-relative timestamp, and the kind-specific payload words.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    pub tid: u64,
    pub seq: u64,
    pub t_ns: u64,
    pub kind: EventKind,
    pub a: u64,
    pub b: u64,
}

struct Slot {
    /// Seqlock generation: `2i+1` while write `i` is in flight, `2i+2` once
    /// published, 0 for never-written.
    seq: AtomicU64,
    t: AtomicU64,
    kd: AtomicU64,
    a: AtomicU64,
    b: AtomicU64,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            t: AtomicU64::new(0),
            kd: AtomicU64::new(0),
            a: AtomicU64::new(0),
            b: AtomicU64::new(0),
        }
    }
}

/// One thread's bounded event ring. **Single-writer**: `push` must only be
/// called by the owning thread (production code enforces this via the
/// thread-local registration in [`record`]); `snapshot_into` may run
/// concurrently from any thread and skips torn slots.
pub struct ThreadRing {
    tid: u64,
    mask: usize,
    head: AtomicU64,
    slots: Box<[Slot]>,
}

impl ThreadRing {
    /// A ring of `cap` slots (`cap` must be a power of two).
    pub fn new(tid: u64, cap: usize) -> ThreadRing {
        assert!(cap.is_power_of_two(), "ring capacity must be a power of two");
        ThreadRing {
            tid,
            mask: cap - 1,
            head: AtomicU64::new(0),
            slots: (0..cap).map(|_| Slot::new()).collect(),
        }
    }

    /// Events ever written (not capped at the ring size).
    pub fn written(&self) -> u64 {
        // ordering: Relaxed — approximate monitoring count; the per-slot
        // seqlock is what guards payload visibility.
        self.head.load(Ordering::Relaxed)
    }

    /// Append one event, overwriting the oldest when full. Owner thread only.
    pub fn push(&self, t_ns: u64, kind: u64, a: u64, b: u64) {
        // ordering: Relaxed — `head` is only ever written by this (owning)
        // thread; this is a read of our own counter.
        let i = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[(i as usize) & self.mask];
        // Seqlock write protocol; tests/model_exec.rs mutation M6 documents
        // what breaks when the publish below moves before the payload.
        // ordering: Relaxed — the Release fence below orders this odd marker
        // before the payload stores for any reader that sees the payload.
        slot.seq.store(2 * i + 1, Ordering::Relaxed);
        fence(Ordering::Release);
        // ordering: Relaxed — payload publication rides the Release store of
        // the even generation below.
        slot.t.store(t_ns, Ordering::Relaxed);
        slot.kd.store(kind, Ordering::Relaxed);
        slot.a.store(a, Ordering::Relaxed);
        slot.b.store(b, Ordering::Relaxed);
        // ordering: Release — publishes the payload: a reader Acquire-loading
        // this even generation observes every payload store above.
        slot.seq.store(2 * i + 2, Ordering::Release);
        // ordering: Relaxed — own-thread counter, approximate for readers.
        self.head.store(i + 1, Ordering::Relaxed);
    }

    /// Copy every cleanly-published slot into `out`, skipping torn or
    /// never-written slots. Safe to call concurrently with `push`.
    pub fn snapshot_into(&self, out: &mut Vec<TraceEvent>) {
        for slot in self.slots.iter() {
            // ordering: Acquire — pairs with the writer's Release publish; a
            // clean even generation here makes the payload loads below see
            // the corresponding payload stores.
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 < 2 || s1 % 2 == 1 {
                continue; // never written, or write in flight
            }
            // ordering: Relaxed — validated by the generation re-read below.
            let t = slot.t.load(Ordering::Relaxed);
            let kd = slot.kd.load(Ordering::Relaxed);
            let a = slot.a.load(Ordering::Relaxed);
            let b = slot.b.load(Ordering::Relaxed);
            // ordering: Acquire fence — seqlock read protocol: orders the
            // payload loads above before the generation re-read below, so a
            // writer that started overwriting mid-read is always detected.
            fence(Ordering::Acquire);
            // ordering: Relaxed — ordered by the fence above.
            let s2 = slot.seq.load(Ordering::Relaxed);
            if s1 != s2 {
                continue; // torn: the writer wrapped past us mid-read
            }
            let Some(kind) = EventKind::from_u64(kd) else { continue };
            out.push(TraceEvent { tid: self.tid, seq: s1 / 2 - 1, t_ns: t, kind, a, b });
        }
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU64 = AtomicU64::new(0);
static NEXT_REQ_ID: AtomicU64 = AtomicU64::new(1);
static REGISTRY: Mutex<Vec<Arc<ThreadRing>>> = Mutex::new(Vec::new());

thread_local! {
    static RING: std::cell::OnceCell<Arc<ThreadRing>> = const { std::cell::OnceCell::new() };
}

/// Turn recording on or off. Off is the default; the disabled cost at every
/// `trace!` site is the single relaxed load in [`enabled`].
pub fn set_enabled(on: bool) {
    // ordering: Relaxed — the flag guards no data; a stale view only starts
    // or stops recording a few events late.
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether the flight recorder is on — the whole disabled-path cost.
#[inline]
pub fn enabled() -> bool {
    // ordering: Relaxed — see `set_enabled`; no payload rides this flag.
    ENABLED.load(Ordering::Relaxed)
}

/// Globally unique request ids for event correlation across threads.
pub fn next_request_id() -> u64 {
    // ordering: Relaxed — uniqueness only needs RMW atomicity.
    NEXT_REQ_ID.fetch_add(1, Ordering::Relaxed)
}

/// Record one event into the calling thread's ring (registering the ring on
/// the thread's first event). Call sites should go through
/// [`trace!`](crate::trace) so the disabled path stays a single branch.
pub fn record(kind: EventKind, a: u64, b: u64) {
    let t = super::clock::now_ns();
    RING.with(|cell| {
        let ring = cell.get_or_init(register_ring);
        ring.push(t, kind as u64, a, b);
    });
}

fn register_ring() -> Arc<ThreadRing> {
    // ordering: Relaxed — tid uniqueness only needs RMW atomicity.
    let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    let ring = Arc::new(ThreadRing::new(tid, DEFAULT_CAP));
    REGISTRY.lock().unwrap().push(ring.clone());
    ring
}

/// Emit a flight-recorder event; compiles to a single relaxed-load branch
/// when recording is off. `$a`/`$b` are only evaluated when recording is on.
#[macro_export]
macro_rules! trace {
    ($kind:expr, $a:expr, $b:expr) => {
        if $crate::obs::trace::enabled() {
            $crate::obs::trace::record($kind, $a as u64, $b as u64);
        }
    };
}

/// Drain every registered ring into one snapshot, sorted by time. Rings keep
/// their contents (a snapshot is a copy, not a consume), so concurrent
/// snapshots and writers never block each other.
pub fn snapshot() -> TraceSnapshot {
    let mut events = Vec::new();
    for ring in REGISTRY.lock().unwrap().iter() {
        ring.snapshot_into(&mut events);
    }
    events.sort_by_key(|e| (e.t_ns, e.tid, e.seq));
    TraceSnapshot { events }
}

/// A drained, time-sorted copy of every thread's ring.
pub struct TraceSnapshot {
    pub events: Vec<TraceEvent>,
}

impl TraceSnapshot {
    /// Events of one kind, in time order.
    pub fn of_kind(&self, kind: EventKind) -> impl Iterator<Item = &TraceEvent> + '_ {
        self.events.iter().filter(move |e| e.kind == kind)
    }

    /// Serialize as Chrome trace-event JSON (the `traceEvents` array form),
    /// loadable in Perfetto or `chrome://tracing`:
    ///
    /// - `SolveStart`/`SolveEnd` and `WarmStart`/`WarmDone|WarmFail` pairs on
    ///   one thread become complete (`"ph":"X"`) spans;
    /// - `Enqueue`→`Respond` pairs matched on the request id become async
    ///   (`"b"`/`"e"`) spans, which Perfetto nests under a per-request track
    ///   so queue-wait → solve → respond reads as a timeline;
    /// - everything else is an instant event.
    pub fn to_chrome_json(&self) -> String {
        let mut out = String::from("{\"traceEvents\":[");
        let mut first = true;
        let mut span_open: std::collections::HashMap<(u64, &'static str), &TraceEvent> =
            std::collections::HashMap::new();
        for e in &self.events {
            let ts = e.t_ns as f64 / 1000.0;
            match e.kind {
                EventKind::SolveStart => {
                    span_open.insert((e.tid, "solve"), e);
                }
                EventKind::WarmStart => {
                    span_open.insert((e.tid, "warm"), e);
                }
                EventKind::SolveEnd | EventKind::WarmDone | EventKind::WarmFail => {
                    let name =
                        if e.kind == EventKind::SolveEnd { "solve" } else { "warm" };
                    if let Some(start) = span_open.remove(&(e.tid, name)) {
                        let ts0 = start.t_ns as f64 / 1000.0;
                        let dur = (e.t_ns.saturating_sub(start.t_ns)) as f64 / 1000.0;
                        push_sep(&mut out, &mut first);
                        out.push_str(&format!(
                            "{{\"name\":\"{name}\",\"ph\":\"X\",\"pid\":1,\"tid\":{},\
                             \"ts\":{ts0:.3},\"dur\":{dur:.3},\
                             \"args\":{{\"a\":{},\"b\":{}}}}}",
                            e.tid, e.a, e.b
                        ));
                    }
                }
                EventKind::Enqueue => {
                    push_sep(&mut out, &mut first);
                    out.push_str(&format!(
                        "{{\"name\":\"request\",\"cat\":\"request\",\"ph\":\"b\",\
                         \"id\":{},\"pid\":1,\"tid\":{},\"ts\":{ts:.3},\
                         \"args\":{{\"kind\":{}}}}}",
                        e.a, e.tid, e.b
                    ));
                }
                EventKind::Respond => {
                    push_sep(&mut out, &mut first);
                    out.push_str(&format!(
                        "{{\"name\":\"request\",\"cat\":\"request\",\"ph\":\"e\",\
                         \"id\":{},\"pid\":1,\"tid\":{},\"ts\":{ts:.3},\
                         \"args\":{{\"latency_us\":{}}}}}",
                        e.a, e.tid, e.b
                    ));
                }
                _ => {
                    push_sep(&mut out, &mut first);
                    out.push_str(&format!(
                        "{{\"name\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\"pid\":1,\
                         \"tid\":{},\"ts\":{ts:.3},\
                         \"args\":{{\"a\":{},\"b\":{}}}}}",
                        e.kind.name(),
                        e.tid,
                        e.a,
                        e.b
                    ));
                }
            }
        }
        out.push_str("]}");
        out
    }
}

fn push_sep(out: &mut String, first: &mut bool) {
    if !*first {
        out.push(',');
    }
    *first = false;
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The enable flag is process-global; tests that toggle it serialize
    /// here so the harness's parallel test threads cannot interleave.
    static FLAG_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn ring_keeps_last_cap_events_and_reads_cleanly() {
        let ring = ThreadRing::new(7, 8);
        for i in 0..20u64 {
            ring.push(i * 10, EventKind::Enqueue as u64, i, i + 1);
        }
        assert_eq!(ring.written(), 20);
        let mut out = Vec::new();
        ring.snapshot_into(&mut out);
        assert_eq!(out.len(), 8, "ring holds exactly cap events");
        out.sort_by_key(|e| e.seq);
        for (k, e) in out.iter().enumerate() {
            let i = 12 + k as u64; // events 12..20 survive
            assert_eq!(e.seq, i);
            assert_eq!(e.t_ns, i * 10);
            assert_eq!(e.a, i);
            assert_eq!(e.b, i + 1);
            assert_eq!(e.tid, 7);
            assert_eq!(e.kind, EventKind::Enqueue);
        }
    }

    #[test]
    fn disabled_macro_skips_payload_evaluation() {
        let _g = FLAG_LOCK.lock().unwrap();
        set_enabled(false);
        let mut evaluated = false;
        let mut probe = || {
            evaluated = true;
            1u64
        };
        crate::trace!(EventKind::Enqueue, probe(), 0);
        assert!(!evaluated, "disabled trace! must not evaluate payload args");
    }

    #[test]
    fn record_drain_roundtrip_via_global_recorder() {
        let _g = FLAG_LOCK.lock().unwrap();
        set_enabled(true);
        let id = next_request_id();
        crate::trace!(EventKind::Enqueue, id, 2);
        crate::trace!(EventKind::Respond, id, 123);
        set_enabled(false);
        let snap = snapshot();
        let enq: Vec<_> = snap.of_kind(EventKind::Enqueue).filter(|e| e.a == id).collect();
        let rsp: Vec<_> = snap.of_kind(EventKind::Respond).filter(|e| e.a == id).collect();
        assert_eq!(enq.len(), 1);
        assert_eq!(rsp.len(), 1);
        assert!(rsp[0].t_ns >= enq[0].t_ns, "snapshot is time-sorted per event");
        assert_eq!(rsp[0].b, 123);
        let json = snap.to_chrome_json();
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.ends_with("]}"));
        assert!(json.contains("\"ph\":\"b\""));
        assert!(json.contains("\"ph\":\"e\""));
    }

    #[test]
    fn solve_pairs_become_complete_spans() {
        let snap = TraceSnapshot {
            events: vec![
                TraceEvent {
                    tid: 3,
                    seq: 0,
                    t_ns: 1_000,
                    kind: EventKind::SolveStart,
                    a: 4,
                    b: 256,
                },
                TraceEvent {
                    tid: 3,
                    seq: 1,
                    t_ns: 51_000,
                    kind: EventKind::SolveEnd,
                    a: 37,
                    b: 120,
                },
            ],
        };
        let json = snap.to_chrome_json();
        assert!(json.contains("\"name\":\"solve\""));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"dur\":50.000"));
    }
}

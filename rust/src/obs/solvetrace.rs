//! Sampled per-solve residual trajectories: Fig. 2-style residual-vs-MVM
//! curves reconstructed from live traffic.
//!
//! `msminres_in`/`msminres_block_in` ask [`should_sample`] once per solve
//! (one relaxed load when sampling is off; a relaxed counter increment and a
//! modulo when on — configurable 1-in-N). A sampled solve's residual history
//! already lives in pooled workspace scratch; at solve exit [`submit`]
//! copies up to [`TRAJ_CAP`] strided points of it (always including the
//! final residual) into one of a fixed set of pre-allocated slots — atomics
//! only, no mutex, no allocation, so the zero-alloc steady-state proofs in
//! `alloc_regression` hold with sampling enabled.
//!
//! Slots are claimed round-robin with an atomic counter and published with
//! the same per-slot seqlock protocol as the flight-recorder ring
//! (`obs/trace.rs`); [`drain`] skips torn slots. A slot is only reused after
//! `SLOTS` further samples, so a drain racing a wrap-around loses (detects)
//! at most the oldest trajectories.

use std::sync::atomic::{fence, AtomicU64, AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Residual points stored per trajectory (longer solves are strided down).
pub const TRAJ_CAP: usize = 128;
/// Trajectory slots held by the sampler (fixed memory: `SLOTS * TRAJ_CAP`
/// residuals plus headers).
pub const SLOTS: usize = 64;

struct TrajSlot {
    /// Seqlock generation: `2k+1` while claim `k` writes, `2k+2` published.
    seq: AtomicU64,
    iters: AtomicU64,
    cols: AtomicU64,
    points: AtomicU64,
    tol_bits: AtomicU64,
    vals: Box<[AtomicU64]>,
}

impl TrajSlot {
    fn new() -> TrajSlot {
        TrajSlot {
            seq: AtomicU64::new(0),
            iters: AtomicU64::new(0),
            cols: AtomicU64::new(0),
            points: AtomicU64::new(0),
            tol_bits: AtomicU64::new(0),
            vals: (0..TRAJ_CAP).map(|_| AtomicU64::new(0)).collect(),
        }
    }
}

static EVERY: AtomicU64 = AtomicU64::new(0);
static COUNTER: AtomicU64 = AtomicU64::new(0);
static NEXT: AtomicUsize = AtomicUsize::new(0);
static SLAB: OnceLock<Box<[TrajSlot]>> = OnceLock::new();

/// Sample one in `every` solves (`0` disables sampling). The slot slab is
/// allocated here, off the solve path, on first enable.
pub fn configure(every: u64) {
    if every > 0 {
        SLAB.get_or_init(|| (0..SLOTS).map(|_| TrajSlot::new()).collect());
    }
    // ordering: Relaxed — the sampling rate guards no data; solvers racing
    // the store just use the old rate for one more solve.
    EVERY.store(every, Ordering::Relaxed);
}

/// Per-solve sampling draw. One relaxed load when sampling is off.
#[inline]
pub fn should_sample() -> bool {
    // ordering: Relaxed — see `configure`; no payload rides the rate.
    let every = EVERY.load(Ordering::Relaxed);
    if every == 0 {
        return false;
    }
    // ordering: Relaxed — the 1-in-N draw only needs RMW atomicity.
    COUNTER.fetch_add(1, Ordering::Relaxed) % every == 0
}

/// Publish one sampled solve's residual history (`history[k]` = relative
/// residual after iteration `k+1`, `iters` entries valid). Strides the
/// history down to at most [`TRAJ_CAP`] points, always keeping the final
/// residual. Atomics only: no mutex, no allocation.
pub fn submit(history: &[f64], iters: usize, cols: usize, tol: f64) {
    let Some(slab) = SLAB.get() else { return };
    let iters = iters.min(history.len());
    if iters == 0 {
        return;
    }
    // ordering: Relaxed — slot claims only need RMW atomicity; the per-slot
    // seqlock below is what publishes the payload.
    let k = NEXT.fetch_add(1, Ordering::Relaxed);
    let slot = &slab[k % SLOTS];
    let gen = 2 * k as u64;
    // Seqlock write protocol (same shape as obs/trace.rs):
    // ordering: Relaxed — the Release fence below orders the odd marker
    // before the payload stores for any reader that sees the payload.
    slot.seq.store(gen + 1, Ordering::Relaxed);
    fence(Ordering::Release);
    let stride = iters.div_ceil(TRAJ_CAP).max(1);
    let mut n = 0usize;
    for j in (0..iters).step_by(stride) {
        // ordering: Relaxed — payload rides the Release publish below.
        slot.vals[n].store(history[j].to_bits(), Ordering::Relaxed);
        n += 1;
    }
    // Termination must be visible even when the stride skips the last
    // iteration: the final point is always the final residual.
    // ordering: Relaxed — payload store, as above.
    slot.vals[n - 1].store(history[iters - 1].to_bits(), Ordering::Relaxed);
    // ordering: Relaxed — payload stores, as above.
    slot.iters.store(iters as u64, Ordering::Relaxed);
    slot.cols.store(cols as u64, Ordering::Relaxed);
    slot.points.store(n as u64, Ordering::Relaxed);
    slot.tol_bits.store(tol.to_bits(), Ordering::Relaxed);
    // ordering: Release — publishes the payload to `drain`'s Acquire load.
    slot.seq.store(gen + 2, Ordering::Release);
}

/// One sampled solve's residual trajectory.
#[derive(Clone, Debug)]
pub struct Trajectory {
    /// Strided relative residuals; the last entry is the final residual.
    pub residuals: Vec<f64>,
    /// True iteration (MVM) count of the solve.
    pub iters: usize,
    /// Right-hand-side columns of the solve (1 for the vector path).
    pub cols: usize,
    /// Convergence tolerance the solve ran with.
    pub tol: f64,
}

/// Copy every cleanly-published trajectory out of the slab (newest-claimed
/// slots last). Skips torn slots; never blocks a sampler.
pub fn drain() -> Vec<Trajectory> {
    let mut out = Vec::new();
    let Some(slab) = SLAB.get() else { return out };
    let mut stamped: Vec<(u64, Trajectory)> = Vec::new();
    for slot in slab.iter() {
        // ordering: Acquire — pairs with `submit`'s Release publish.
        let s1 = slot.seq.load(Ordering::Acquire);
        if s1 < 2 || s1 % 2 == 1 {
            continue;
        }
        let n = slot.points.load(Ordering::Relaxed) as usize;
        if n == 0 || n > TRAJ_CAP {
            continue;
        }
        let mut residuals = Vec::with_capacity(n);
        for v in slot.vals.iter().take(n) {
            // ordering: Relaxed — validated by the generation re-read below.
            residuals.push(f64::from_bits(v.load(Ordering::Relaxed)));
        }
        let iters = slot.iters.load(Ordering::Relaxed) as usize;
        let cols = slot.cols.load(Ordering::Relaxed) as usize;
        let tol = f64::from_bits(slot.tol_bits.load(Ordering::Relaxed));
        // ordering: Acquire fence — seqlock read protocol: orders the payload
        // loads above before the generation re-read below.
        fence(Ordering::Acquire);
        // ordering: Relaxed — ordered by the fence above.
        let s2 = slot.seq.load(Ordering::Relaxed);
        if s1 != s2 {
            continue;
        }
        stamped.push((s1, Trajectory { residuals, iters, cols, tol }));
    }
    stamped.sort_by_key(|(gen, _)| *gen);
    out.extend(stamped.into_iter().map(|(_, t)| t));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // One combined test: the sampler state (rate, draw counter, slab) is
    // process-global, so splitting these into parallel #[test]s would race
    // on `configure`.
    #[test]
    fn sampling_draw_honors_rate_and_strided_submit_keeps_final() {
        configure(1);
        assert!(should_sample());
        // A long monotone history strides down to TRAJ_CAP points with the
        // final residual preserved exactly.
        let iters = 1000usize;
        let history: Vec<f64> = (0..iters).map(|k| 1.0 / (k as f64 + 1.0)).collect();
        submit(&history, iters, 4, 1e-8);
        let trajs = drain();
        let t = trajs.last().expect("one trajectory published");
        assert_eq!(t.iters, iters);
        assert_eq!(t.cols, 4);
        assert!(t.residuals.len() <= TRAJ_CAP);
        assert_eq!(*t.residuals.last().unwrap(), 1.0 / iters as f64);
        for w in t.residuals.windows(2) {
            assert!(w[1] <= w[0], "strided trajectory stays monotone");
        }
        configure(0);
        assert!(!should_sample());

        // 1-in-N draw: the modulo counter is shared process-wide, so allow
        // slack for any concurrent solver test consuming draws.
        configure(4);
        let hits = (0..400).filter(|_| should_sample()).count();
        assert!((80..=120).contains(&hits), "1-in-4 sampling drew {hits}/400");
        configure(0);
    }
}

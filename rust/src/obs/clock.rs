//! Instrumented monotonic clock: nanoseconds since a lazily-pinned process
//! epoch.
//!
//! All observability timestamps flow through [`now_ns`] so that (a) events
//! from different threads share one time base and serialize as plain `u64`s,
//! and (b) everything downstream of the timestamp (histograms, the flight
//! recorder, snapshots) is testable with synthetic times — the data
//! structures take explicit `u64` timestamps and never read the clock
//! themselves. `obs/` and `exec/timer.rs` are the only modules allowed to
//! call `Instant::now()` without a `// clock:` justification (structlint
//! rule 6); everyone else either takes a timestamp or documents why it owns
//! a raw clock read.

use std::sync::OnceLock;
use std::time::Instant;

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// The process epoch: pinned at the first call, shared by every thread.
pub fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Monotonic nanoseconds since the process epoch. First call returns 0.
#[inline]
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

/// Nanoseconds since the epoch at some earlier `Instant` (saturating to 0
/// for instants taken before the epoch was pinned).
pub fn instant_ns(t: Instant) -> u64 {
    t.saturating_duration_since(epoch()).as_nanos() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_and_epoch_relative() {
        let a = now_ns();
        let b = now_ns();
        assert!(b >= a);
        let t = Instant::now(); // clock: test probe comparing against now_ns
        assert!(instant_ns(t) >= a);
        // an instant from before the epoch saturates to 0, never panics
        if let Some(t0) = epoch().checked_sub(std::time::Duration::from_secs(1)) {
            assert_eq!(instant_ns(t0), 0);
        }
    }
}

//! Typed, exportable service snapshots: the machine-readable successor to
//! the coordinator's format-string-only `summary()`.
//!
//! [`MetricsSnapshot`] is a plain-data copy of every service counter, the
//! three telemetry histograms, the per-shard controller state, and the
//! executor's liveness counters. It serializes as JSON (`to_json`) and as
//! Prometheus text exposition format (`to_prometheus`); `to_line` renders
//! the legacy one-line log summary so existing log scrapers keep working.
//! All serializers are hand-rolled — the crate stays dependency-free.

use super::hist::HistSnapshot;

/// Executor-layer liveness counters (async backend), copied out of
/// `exec::ExecStats` at snapshot time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExecSnapshot {
    pub parks: u64,
    pub wakeups: u64,
    pub polls: u64,
    pub timer_fires: u64,
}

/// Point-in-time copy of the whole service's telemetry. Counters are read
/// relaxed and independently: totals are exact per counter, cross-counter
/// consistency is approximate (standard monitoring semantics).
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub policy: String,
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub operator_replacements: u64,
    pub warmed_operators: u64,
    pub warm_failures: u64,
    pub warm_starts: u64,
    pub workspace_checkouts: u64,
    pub workspace_grows: u64,
    pub workspace_bytes_high_water: u64,
    pub saved_mvms: u64,
    pub saved_column_work: u64,
    pub column_work: u64,
    pub dispatcher_wakeups: u64,
    pub timer_fires: u64,
    pub dense_solves: u64,
    pub dense_fallbacks: u64,
    pub dense_factor_builds: u64,
    pub dense_crossover_n: u64,
    /// Krylov block solves executed in pure f64 (fallbacks included).
    pub solves_f64: u64,
    /// Krylov block solves served by the mixed-precision engine.
    pub solves_mixed: u64,
    /// Iterative-refinement sweeps spent by mixed solves.
    pub refine_sweeps: u64,
    /// Mixed solves that stagnated and re-ran in pure f64.
    pub precision_fallbacks: u64,
    /// End-to-end request latency in µs.
    pub latency_us: HistSnapshot,
    /// Dispatched batch sizes.
    pub batch_sizes: HistSnapshot,
    /// msMINRES iterations per served RHS (the Fig. S7 data).
    pub iterations: HistSnapshot,
    /// `(shard, current depth, max depth)`, sorted.
    pub shard_depths: Vec<(String, usize, usize)>,
    /// `(shard, adaptive batch ceiling)`, sorted.
    pub batch_ceilings: Vec<(String, usize)>,
    /// `(shard, adaptive flush wait µs)`, sorted.
    pub shard_waits: Vec<(String, u64)>,
    /// `(size-class shard, requests served dense)`, sorted.
    pub dense_shards: Vec<(String, u64)>,
    /// Executor counters when the async backend runs.
    pub exec: Option<ExecSnapshot>,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Prometheus label values escape `\`, `"` and newlines.
fn prom_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn json_hist(h: &HistSnapshot) -> String {
    let mut out = format!(
        "{{\"count\":{},\"sum\":{},\"max\":{},\"p50\":{},\"p99\":{},\"p999\":{},\"buckets\":[",
        h.count(),
        h.sum(),
        h.max(),
        json_opt(h.percentile(50.0)),
        json_opt(h.percentile(99.0)),
        json_opt(h.percentile(99.9)),
    );
    let mut first = true;
    for (lo, hi, c) in h.buckets() {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&format!("[{lo},{hi},{c}]"));
    }
    out.push_str("]}");
    out
}

fn json_opt(v: Option<u64>) -> String {
    match v {
        Some(v) => v.to_string(),
        None => "null".to_string(),
    }
}

impl MetricsSnapshot {
    /// The snapshot as a single JSON object (counters, histograms with
    /// non-empty buckets, per-shard maps, executor counters).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{");
        out.push_str(&format!("\"policy\":\"{}\"", json_escape(&self.policy)));
        for (k, v) in self.counters() {
            out.push_str(&format!(",\"{k}\":{v}"));
        }
        out.push_str(&format!(",\"latency_us\":{}", json_hist(&self.latency_us)));
        out.push_str(&format!(",\"batch_sizes\":{}", json_hist(&self.batch_sizes)));
        out.push_str(&format!(",\"iterations\":{}", json_hist(&self.iterations)));
        out.push_str(",\"shard_depths\":{");
        for (i, (k, cur, max)) in self.shard_depths.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{}\":[{cur},{max}]", json_escape(k)));
        }
        out.push_str("},\"batch_ceilings\":{");
        for (i, (k, c)) in self.batch_ceilings.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{}\":{c}", json_escape(k)));
        }
        out.push_str("},\"shard_waits_us\":{");
        for (i, (k, us)) in self.shard_waits.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{}\":{us}", json_escape(k)));
        }
        out.push_str("},\"dense_shards\":{");
        for (i, (k, c)) in self.dense_shards.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{}\":{c}", json_escape(k)));
        }
        out.push('}');
        match &self.exec {
            Some(e) => out.push_str(&format!(
                ",\"exec\":{{\"parks\":{},\"wakeups\":{},\"polls\":{},\"timer_fires\":{}}}",
                e.parks, e.wakeups, e.polls, e.timer_fires
            )),
            None => out.push_str(",\"exec\":null"),
        }
        out.push('}');
        out
    }

    /// The snapshot in Prometheus text exposition format: counters as
    /// `counter`, histograms as `summary` quantiles (p50/p99/p999 with the
    /// documented ≤ 6.25 % overshoot), per-shard state as labeled gauges.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters() {
            out.push_str(&format!("# TYPE ciq_{k} counter\nciq_{k} {v}\n"));
        }
        for (name, h) in [
            ("request_latency_us", &self.latency_us),
            ("batch_size", &self.batch_sizes),
            ("solve_iterations", &self.iterations),
        ] {
            out.push_str(&format!("# TYPE ciq_{name} summary\n"));
            for (q, p) in [("0.5", 50.0), ("0.99", 99.0), ("0.999", 99.9)] {
                if let Some(v) = h.percentile(p) {
                    out.push_str(&format!("ciq_{name}{{quantile=\"{q}\"}} {v}\n"));
                }
            }
            out.push_str(&format!("ciq_{name}_sum {}\n", h.sum()));
            out.push_str(&format!("ciq_{name}_count {}\n", h.count()));
        }
        out.push_str("# TYPE ciq_shard_depth gauge\n");
        for (k, cur, _) in &self.shard_depths {
            out.push_str(&format!("ciq_shard_depth{{shard=\"{}\"}} {cur}\n", prom_escape(k)));
        }
        out.push_str("# TYPE ciq_shard_batch_ceiling gauge\n");
        for (k, c) in &self.batch_ceilings {
            out.push_str(&format!(
                "ciq_shard_batch_ceiling{{shard=\"{}\"}} {c}\n",
                prom_escape(k)
            ));
        }
        out.push_str("# TYPE ciq_shard_wait_us gauge\n");
        for (k, us) in &self.shard_waits {
            out.push_str(&format!("ciq_shard_wait_us{{shard=\"{}\"}} {us}\n", prom_escape(k)));
        }
        out.push_str("# TYPE ciq_dense_shard_solves counter\n");
        for (k, c) in &self.dense_shards {
            out.push_str(&format!("ciq_dense_shard_solves{{shard=\"{}\"}} {c}\n", prom_escape(k)));
        }
        if let Some(e) = &self.exec {
            for (k, v) in [
                ("exec_parks", e.parks),
                ("exec_wakeups", e.wakeups),
                ("exec_polls", e.polls),
                ("exec_timer_fires", e.timer_fires),
            ] {
                out.push_str(&format!("# TYPE ciq_{k} counter\nciq_{k} {v}\n"));
            }
        }
        out
    }

    /// The legacy one-line log summary (`Metrics::summary` delegates here).
    pub fn to_line(&self) -> String {
        format!(
            "policy={} submitted={} completed={} failed={} p50={}us p99={}us mean_batch={:.1} \
             mean_iters={:.1} cache_hit={} cache_miss={} warmed={} warm_starts={} saved_mvms={} \
             saved_colwork={} wakeups={} timer_fires={} ws_checkouts={} ws_grows={} ws_peak_bytes={} \
             dense_solves={} dense_fallbacks={} dense_builds={} dense_crossover_n={} \
             solves_f64={} solves_mixed={} refine_sweeps={} precision_fallbacks={}",
            self.policy,
            self.submitted,
            self.completed,
            self.failed,
            self.latency_us.percentile(50.0).unwrap_or(0),
            self.latency_us.percentile(99.0).unwrap_or(0),
            self.batch_sizes.mean(),
            self.iterations.mean(),
            self.cache_hits,
            self.cache_misses,
            self.warmed_operators,
            self.warm_starts,
            self.saved_mvms,
            self.saved_column_work,
            self.dispatcher_wakeups,
            self.timer_fires,
            self.workspace_checkouts,
            self.workspace_grows,
            self.workspace_bytes_high_water,
            self.dense_solves,
            self.dense_fallbacks,
            self.dense_factor_builds,
            self.dense_crossover_n,
            self.solves_f64,
            self.solves_mixed,
            self.refine_sweeps,
            self.precision_fallbacks,
        )
    }

    /// The scalar counters as stable `(name, value)` pairs — the one list
    /// both serializers iterate, so they can never drift apart.
    fn counters(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("requests_submitted", self.submitted),
            ("requests_completed", self.completed),
            ("requests_failed", self.failed),
            ("cache_hits", self.cache_hits),
            ("cache_misses", self.cache_misses),
            ("operator_replacements", self.operator_replacements),
            ("warmed_operators", self.warmed_operators),
            ("warm_failures", self.warm_failures),
            ("warm_starts", self.warm_starts),
            ("workspace_checkouts", self.workspace_checkouts),
            ("workspace_grows", self.workspace_grows),
            ("workspace_bytes_high_water", self.workspace_bytes_high_water),
            ("saved_mvms", self.saved_mvms),
            ("saved_column_work", self.saved_column_work),
            ("column_work", self.column_work),
            ("dispatcher_wakeups", self.dispatcher_wakeups),
            ("timer_fires", self.timer_fires),
            ("dense_solves", self.dense_solves),
            ("dense_fallbacks", self.dense_fallbacks),
            ("dense_factor_builds", self.dense_factor_builds),
            ("dense_crossover_n", self.dense_crossover_n),
            ("solves_f64", self.solves_f64),
            ("solves_mixed", self.solves_mixed),
            ("refine_sweeps", self.refine_sweeps),
            ("precision_fallbacks", self.precision_fallbacks),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::hist::AtomicHistogram;

    fn sample() -> MetricsSnapshot {
        let lat = AtomicHistogram::new();
        lat.record(250);
        lat.record(900);
        let batch = AtomicHistogram::new();
        batch.record(4);
        let iters = AtomicHistogram::new();
        iters.record(37);
        MetricsSnapshot {
            policy: "CachedBounds".into(),
            submitted: 2,
            completed: 2,
            failed: 0,
            cache_hits: 1,
            cache_misses: 1,
            operator_replacements: 0,
            warmed_operators: 1,
            warm_failures: 0,
            warm_starts: 0,
            workspace_checkouts: 2,
            workspace_grows: 1,
            workspace_bytes_high_water: 4096,
            saved_mvms: 15,
            saved_column_work: 8,
            column_work: 40,
            dispatcher_wakeups: 2,
            timer_fires: 1,
            dense_solves: 0,
            dense_fallbacks: 0,
            dense_factor_builds: 0,
            dense_crossover_n: 0,
            solves_f64: 3,
            solves_mixed: 2,
            refine_sweeps: 5,
            precision_fallbacks: 1,
            latency_us: lat.snapshot(),
            batch_sizes: batch.snapshot(),
            iterations: iters.snapshot(),
            shard_depths: vec![("a/Sample".into(), 1, 3)],
            batch_ceilings: vec![("a/Sample".into(), 16)],
            shard_waits: vec![("a/Sample".into(), 1500)],
            dense_shards: vec![],
            exec: Some(ExecSnapshot { parks: 5, wakeups: 6, polls: 7, timer_fires: 1 }),
        }
    }

    #[test]
    fn json_contains_counters_histograms_and_shards() {
        let s = sample().to_json();
        assert!(s.starts_with('{') && s.ends_with('}'));
        assert!(s.contains("\"policy\":\"CachedBounds\""));
        assert!(s.contains("\"requests_submitted\":2"));
        assert!(s.contains("\"latency_us\":{\"count\":2"));
        assert!(s.contains("\"shard_depths\":{\"a/Sample\":[1,3]}"));
        assert!(s.contains("\"exec\":{\"parks\":5"));
        // crude structural check: balanced braces and quotes
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('"').count() % 2, 0);
    }

    #[test]
    fn prometheus_exposition_shape() {
        let s = sample().to_prometheus();
        assert!(s.contains("# TYPE ciq_requests_completed counter\nciq_requests_completed 2\n"));
        assert!(s.contains("# TYPE ciq_request_latency_us summary\n"));
        assert!(s.contains("ciq_request_latency_us{quantile=\"0.5\"}"));
        assert!(s.contains("ciq_request_latency_us_count 2\n"));
        assert!(s.contains("ciq_shard_depth{shard=\"a/Sample\"} 1\n"));
        assert!(s.contains("ciq_exec_polls 7\n"));
        // every non-comment line is `name{labels} value` or `name value`
        for line in s.lines().filter(|l| !l.starts_with('#')) {
            let (name, value) = line.rsplit_once(' ').expect("metric line has a value");
            assert!(!name.is_empty());
            assert!(value.parse::<f64>().is_ok(), "unparsable value in {line:?}");
        }
    }

    #[test]
    fn legacy_line_format_preserved() {
        let line = sample().to_line();
        assert!(line.contains("policy=CachedBounds"));
        assert!(line.contains("cache_hit=1"));
        assert!(line.contains("mean_batch=4.0"));
        assert!(line.contains("dense_crossover_n=0"));
        assert!(line.contains("solves_mixed=2"));
        assert!(line.contains("refine_sweeps=5"));
        assert!(line.contains("precision_fallbacks=1"));
    }

    #[test]
    fn escaping_is_safe_for_hostile_names() {
        let mut s = sample();
        s.policy = "quo\"te\\back\nnew".into();
        s.shard_depths = vec![("bad\"shard".into(), 0, 0)];
        let json = s.to_json();
        assert!(json.contains("quo\\\"te\\\\back\\nnew"));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        let prom = s.to_prometheus();
        assert!(prom.contains("shard=\"bad\\\"shard\""));
    }
}

//! Lock-free log-bucketed histograms (HDR-style) for hot-path telemetry.
//!
//! Layout: values below `2^SUB_BITS` (= 32) land in one exact bucket each;
//! above that, each power-of-two octave is split into `2^(SUB_BITS-1)` (= 16)
//! linear sub-buckets. A bucket covering `[lo, hi]` therefore has
//! `(hi - lo) / lo < 1/16`, so any percentile reported from the bucket upper
//! bound overshoots the true sample by **at most 6.25 %** (`REL_ERR`), and
//! values `< 32` are exact. The whole `u64` range fits in `NUM_BUCKETS` = 976
//! counters (~7.6 KiB), so memory is fixed no matter how long the service
//! runs — unlike the unbounded `Mutex<Vec<u64>>` this replaces.
//!
//! `record` is one `fetch_add` on the bucket plus three bookkeeping atomics
//! (count/sum/max): O(1), wait-free, no mutex, no allocation. `percentile`
//! copies the counters into a fixed stack array and walks it: O(buckets) and
//! allocation-free (regression-tested in `alloc_regression`). Snapshots are
//! plain counter vectors and merge by addition, so per-shard or per-process
//! histograms aggregate losslessly.

use std::sync::atomic::{AtomicU64, Ordering};

/// log₂ of the number of exact low buckets; each octave above them gets
/// `2^(SUB_BITS-1)` linear sub-buckets.
pub const SUB_BITS: u32 = 5;
const SUBS: u64 = 1 << SUB_BITS; // 32 exact buckets for values 0..32
const HALF: usize = (SUBS / 2) as usize; // 16 sub-buckets per octave
/// Total bucket count covering the full `u64` range.
pub const NUM_BUCKETS: usize = SUBS as usize + (64 - SUB_BITS as usize) * HALF;
/// Documented relative-error bound of percentile reports: the reported value
/// is `>=` the true sample and overshoots it by at most this factor.
pub const REL_ERR: f64 = 1.0 / HALF as f64;

/// Bucket index for a value: identity below `SUBS`, log-linear above.
#[inline]
fn bucket_index(v: u64) -> usize {
    if v < SUBS {
        v as usize
    } else {
        let msb = 63 - v.leading_zeros(); // >= SUB_BITS since v >= SUBS
        let major = (msb - SUB_BITS + 1) as usize;
        let sub = (v >> major) as usize; // in [HALF, 2*HALF)
        SUBS as usize + (major - 1) * HALF + (sub - HALF)
    }
}

/// Inclusive lower bound of bucket `i`.
fn bucket_lo(i: usize) -> u64 {
    if i < SUBS as usize {
        i as u64
    } else {
        let major = (i - SUBS as usize) / HALF + 1;
        let off = (i - SUBS as usize) % HALF;
        ((HALF + off) as u64) << major
    }
}

/// Inclusive upper bound of bucket `i`.
fn bucket_hi(i: usize) -> u64 {
    if i < SUBS as usize {
        i as u64
    } else {
        let major = (i - SUBS as usize) / HALF + 1;
        bucket_lo(i) + (1u64 << major) - 1
    }
}

/// Fixed-memory concurrent histogram: O(1) wait-free `record`, O(buckets)
/// allocation-free `percentile`, mergeable [`HistSnapshot`]s. See the module
/// docs for the bucketing scheme and the `REL_ERR` error bound.
pub struct AtomicHistogram {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for AtomicHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl AtomicHistogram {
    pub fn new() -> Self {
        AtomicHistogram {
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one sample. Wait-free: four relaxed atomic RMWs, no branch on
    /// contention, no allocation.
    #[inline]
    pub fn record(&self, v: u64) {
        // ordering: Relaxed — independent telemetry counters; readers take
        // approximate snapshots and never need cross-counter consistency.
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        // ordering: Relaxed — monitoring read of an independent counter.
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all recorded samples (exact, unlike the bucketed values).
    pub fn sum(&self) -> u64 {
        // ordering: Relaxed — monitoring read of an independent counter.
        self.sum.load(Ordering::Relaxed)
    }

    /// Largest recorded sample (exact).
    pub fn max(&self) -> u64 {
        // ordering: Relaxed — monitoring read of an independent counter.
        self.max.load(Ordering::Relaxed)
    }

    /// Mean of recorded samples, 0.0 when empty (sum and count are exact).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    /// Percentile `p` in `[0, 100]`, or `None` when no samples were recorded
    /// (distinguishing "no data" from a true 0 sample — the bug the old
    /// clone-and-sort path had). The result is the bucket upper bound capped
    /// at the observed max: `true <= reported <= true * (1 + REL_ERR)`.
    /// Allocation-free: the counters are copied into a fixed stack array.
    pub fn percentile(&self, p: f64) -> Option<u64> {
        let mut counts = [0u64; NUM_BUCKETS];
        let mut total = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            // ordering: Relaxed — approximate snapshot; racing records may
            // land on either side of the copy, both are valid reports.
            let c = b.load(Ordering::Relaxed);
            counts[i] = c;
            total += c;
        }
        percentile_from(&counts, total, self.max(), p)
    }

    /// Point-in-time copy of the counters for merging and serialization.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut counts = vec![0u64; NUM_BUCKETS];
        let mut total = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            // ordering: Relaxed — approximate snapshot, as in `percentile`.
            let c = b.load(Ordering::Relaxed);
            counts[i] = c;
            total += c;
        }
        HistSnapshot { counts, count: total, sum: self.sum(), max: self.max() }
    }
}

/// Shared percentile walk over a counter array: rank `ceil(p/100 * total)`
/// (clamped to `[1, total]`), reported as the covering bucket's upper bound
/// capped at `max`.
fn percentile_from(counts: &[u64], total: u64, max: u64, p: f64) -> Option<u64> {
    if total == 0 {
        return None;
    }
    let rank = ((p.clamp(0.0, 100.0) / 100.0) * total as f64).ceil() as u64;
    let rank = rank.clamp(1, total);
    let mut seen = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        seen += c;
        if seen >= rank {
            return Some(bucket_hi(i).min(max));
        }
    }
    Some(max)
}

/// Mergeable point-in-time histogram snapshot. `count` is the sum of the
/// bucket counters at copy time (racing `record`s may make the independently
/// read `sum`/`max` trail or lead by a few samples; all reads are valid
/// telemetry).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    counts: Vec<u64>,
    count: u64,
    sum: u64,
    max: u64,
}

impl HistSnapshot {
    /// An empty snapshot (identity element for `merge`).
    pub fn empty() -> Self {
        HistSnapshot { counts: vec![0u64; NUM_BUCKETS], count: 0, sum: 0, max: 0 }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Same semantics and error bound as [`AtomicHistogram::percentile`].
    pub fn percentile(&self, p: f64) -> Option<u64> {
        percentile_from(&self.counts, self.count, self.max, p)
    }

    /// Fold another snapshot into this one (counters add, maxima max): the
    /// merge of per-shard histograms is the histogram of the union.
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    /// Non-empty buckets as `(lo, hi, count)`, ascending.
    pub fn buckets(&self) -> impl Iterator<Item = (u64, u64, u64)> + '_ {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (bucket_lo(i), bucket_hi(i), c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_monotone_and_contiguous() {
        // Every bucket boundary maps to itself and indices never decrease.
        let mut prev = 0usize;
        for v in 0..4096u64 {
            let i = bucket_index(v);
            assert!(i >= prev, "index decreased at v={v}");
            assert!(bucket_lo(i) <= v && v <= bucket_hi(i), "v={v} outside bucket {i}");
            prev = i;
        }
        for shift in SUB_BITS..63 {
            let v = 1u64 << shift;
            for probe in [v - 1, v, v + 1, v + (v >> 1)] {
                let i = bucket_index(probe);
                assert!(bucket_lo(i) <= probe && probe <= bucket_hi(i));
            }
        }
        assert!(bucket_index(u64::MAX) < NUM_BUCKETS);
        assert_eq!(bucket_index(u64::MAX), NUM_BUCKETS - 1);
    }

    #[test]
    fn small_values_are_exact() {
        let h = AtomicHistogram::new();
        for v in [0u64, 1, 5, 12, 13, 27, 31] {
            h.record(v);
        }
        assert_eq!(h.percentile(0.0), Some(0));
        assert_eq!(h.percentile(100.0), Some(31));
        // rank 4 of 7 → the 4th smallest = 12
        assert_eq!(h.percentile(50.0), Some(12));
        assert_eq!(h.count(), 7);
        assert_eq!(h.sum(), 89);
        assert_eq!(h.max(), 31);
    }

    #[test]
    fn empty_histogram_reports_none_and_zero_is_distinct() {
        // Regression for the old `latency_percentile_us` conflating "no
        // data" with a true 0 µs sample.
        let h = AtomicHistogram::new();
        assert_eq!(h.percentile(50.0), None);
        assert_eq!(h.snapshot().percentile(99.0), None);
        h.record(0);
        assert_eq!(h.percentile(50.0), Some(0));
    }

    #[test]
    fn percentiles_within_documented_error_bound() {
        // Log-uniform synthetic distribution: exact sorted percentiles vs
        // histogram reports must satisfy true <= reported <= true*(1+REL_ERR).
        let h = AtomicHistogram::new();
        let mut vals: Vec<u64> = Vec::new();
        let mut x = 17u64;
        for i in 0..10_000u64 {
            // xorshift; spread samples over ~6 decades
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let v = (x % 1_000_000).max(i % 97);
            vals.push(v);
            h.record(v);
        }
        vals.sort_unstable();
        for p in [50.0, 90.0, 99.0, 99.9] {
            let rank = ((p / 100.0) * vals.len() as f64).ceil() as usize;
            let truth = vals[rank.clamp(1, vals.len()) - 1];
            let got = h.percentile(p).unwrap();
            assert!(got >= truth, "p{p}: reported {got} < true {truth}");
            let bound = (truth as f64 * (1.0 + REL_ERR)).ceil() as u64;
            assert!(got <= bound, "p{p}: reported {got} > bound {bound} (true {truth})");
        }
    }

    #[test]
    fn snapshots_merge_to_union() {
        let a = AtomicHistogram::new();
        let b = AtomicHistogram::new();
        let u = AtomicHistogram::new();
        for v in [3u64, 700, 45_000] {
            a.record(v);
            u.record(v);
        }
        for v in [9u64, 801, 2_000_000] {
            b.record(v);
            u.record(v);
        }
        let mut m = a.snapshot();
        m.merge(&b.snapshot());
        assert_eq!(m, u.snapshot());
        assert_eq!(m.count(), 6);
        assert_eq!(m.max(), 2_000_000);
        assert_eq!(m.buckets().map(|(_, _, c)| c).sum::<u64>(), 6);
    }

    #[test]
    fn concurrent_records_all_land() {
        let h = std::sync::Arc::new(AtomicHistogram::new());
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..10_000u64 {
                        h.record(t * 1000 + i % 500);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 40_000);
        assert_eq!(h.snapshot().count(), 40_000);
    }
}

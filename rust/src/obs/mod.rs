//! Observability: the dependency-free instrumentation layer under the
//! serving stack (DESIGN.md §8).
//!
//! Three pillars, each costing (provably) nothing when idle or disabled:
//!
//! - [`hist`] — lock-free HDR-style [`hist::AtomicHistogram`]s: fixed
//!   memory, O(1) wait-free record, percentiles within a documented ≤ 6.25 %
//!   relative error, mergeable snapshots. These back the coordinator's
//!   latency / batch-size / iteration telemetry, replacing unbounded
//!   `Mutex<Vec<_>>`s on the completion path.
//! - [`trace`] — a flight recorder: per-thread seqlock-published event
//!   rings behind the [`trace!`](crate::trace) macro (one relaxed-load
//!   branch when off), drained to a [`trace::TraceSnapshot`] and exportable
//!   as Chrome trace-event JSON for Perfetto.
//! - [`solvetrace`] + [`snapshot`] — 1-in-N sampled per-solve residual
//!   trajectories out of `msminres_in`/`msminres_block_in` (Fig. 2 curves
//!   from live traffic), and the typed, JSON/Prometheus-serializable
//!   [`snapshot::MetricsSnapshot`].
//!
//! [`clock`] pins the shared monotonic time base; structlint rule 6 keeps
//! every other `Instant::now()`/`SystemTime::now()` in the tree justified
//! with a `// clock:` comment so timing stays auditable and mockable.

pub mod clock;
pub mod hist;
pub mod snapshot;
pub mod solvetrace;
pub mod trace;

pub use hist::{AtomicHistogram, HistSnapshot};
pub use snapshot::{ExecSnapshot, MetricsSnapshot};
pub use trace::{EventKind, TraceEvent, TraceSnapshot};

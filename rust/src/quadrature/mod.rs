//! Hale–Higham–Trefethen contour-integral quadrature for `K^{±1/2}`
//! (Appx. B of the paper; Alg. 2).
//!
//! Given spectral bounds `0 < λ_min ≤ λ_max`, produces `Q` positive weights
//! `w_q` and shifts `t_q` such that
//! `K^{-1/2} ≈ Σ_q w_q (t_q I + K)^{-1}` with error decaying like
//! `O(exp(−2Qπ² / (log κ + 3)))` (Lemma 1) — i.e. only *logarithmically*
//! dependent on the conditioning, so `Q ≈ 8` suffices even for κ ≈ 10⁴.

use crate::special::{ellipj, ellipk_modulus};
use crate::{Error, Result};

/// A contour-integral quadrature rule for the inverse square root.
#[derive(Clone, Debug)]
pub struct QuadratureRule {
    /// Positive weights `w_q`.
    pub weights: Vec<f64>,
    /// Positive shifts `t_q` (each `t_q I + K` is SPD).
    pub shifts: Vec<f64>,
    /// The λ_min used to build the rule.
    pub lambda_min: f64,
    /// The λ_max used to build the rule.
    pub lambda_max: f64,
}

impl QuadratureRule {
    /// Scalar evaluation `Σ_q w_q / (t_q + x) ≈ x^{-1/2}` — handy for tests
    /// and for error diagnostics.
    pub fn eval_inv_sqrt(&self, x: f64) -> f64 {
        self.weights
            .iter()
            .zip(&self.shifts)
            .map(|(w, t)| w / (t + x))
            .sum()
    }

    /// The Lemma-1 quadrature error bound `O(exp(-2Qπ²/(log κ + 3)))`
    /// (without the constant).
    pub fn error_bound(&self) -> f64 {
        let kappa = self.lambda_max / self.lambda_min;
        let q = self.weights.len() as f64;
        (-2.0 * q * std::f64::consts::PI.powi(2) / (kappa.ln() + 3.0)).exp()
    }
}

/// Build the `Q`-point quadrature rule of Eq. (S4)/(S5) from spectral bounds.
///
/// Implements Alg. 2: elliptic modulus `k² = λ_min/λ_max`, complete elliptic
/// integral `K'(k) = K(k')`, Jacobi elliptic functions at the midpoint nodes
/// `u_q = (q − ½)/Q` evaluated through Jacobi's imaginary transformation.
pub fn ciq_quadrature(q_points: usize, lambda_min: f64, lambda_max: f64) -> Result<QuadratureRule> {
    if !(lambda_min > 0.0 && lambda_max >= lambda_min) {
        return Err(Error::Invalid(format!(
            "need 0 < lambda_min <= lambda_max, got ({lambda_min}, {lambda_max})"
        )));
    }
    if q_points == 0 {
        return Err(Error::Invalid("need at least one quadrature point".into()));
    }
    // guard the degenerate perfectly-conditioned case (k → 1)
    let lambda_max = if lambda_max / lambda_min < 1.0 + 1e-10 {
        lambda_min * (1.0 + 1e-6)
    } else {
        lambda_max
    };
    let k2 = lambda_min / lambda_max; // squared elliptic modulus
    let kp = (1.0 - k2).sqrt(); // complementary modulus k'
    let big_kp = ellipk_modulus(kp); // K'(k) = K(k')

    let mut weights = Vec::with_capacity(q_points);
    let mut shifts = Vec::with_capacity(q_points);
    for q in 1..=q_points {
        let u = (q as f64 - 0.5) / q_points as f64;
        // sn/cn/dn with modulus k' (parameter m = k'²) at u·K'(k)
        let (sn_b, cn_b, dn_b) = ellipj(u * big_kp, kp * kp);
        // Jacobi imaginary transformation to modulus k:
        //   sn(i u K'|k) = i sn̄/cn̄,  cn = 1/cn̄,  dn = dn̄/cn̄
        // => t_q = −σ_q² = −λ_min·sn² = λ_min·(sn̄/cn̄)² > 0
        // => w_q = −w̃_q = (2√λ_min)/(πQ)·K'·cn·dn = (2√λ_min K' dn̄)/(πQ cn̄²)
        let sn_ratio = sn_b / cn_b;
        let t_q = lambda_min * sn_ratio * sn_ratio;
        let w_q = 2.0 * lambda_min.sqrt() * big_kp * dn_b
            / (std::f64::consts::PI * q_points as f64 * cn_b * cn_b);
        if !(t_q.is_finite() && w_q.is_finite()) {
            return Err(Error::Numerical(format!(
                "quadrature node {q} not finite (kappa={})",
                lambda_max / lambda_min
            )));
        }
        shifts.push(t_q);
        weights.push(w_q);
    }
    Ok(QuadratureRule { weights, shifts, lambda_min, lambda_max })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_inverse_sqrt_converges() {
        // On [λmin, λmax], the rule should approximate x^{-1/2} to near
        // machine precision with modest Q.
        let rule = ciq_quadrature(12, 0.5, 50.0).unwrap();
        for &x in &[0.5, 1.0, 3.0, 10.0, 50.0] {
            let approx = rule.eval_inv_sqrt(x);
            let exact = 1.0 / x.sqrt();
            assert!(
                (approx - exact).abs() / exact < 1e-9,
                "x={x}: {approx} vs {exact}"
            );
        }
    }

    #[test]
    fn error_decays_exponentially_in_q() {
        let (lo, hi) = (1e-3f64, 1.0f64); // kappa = 1000
        let probe = |rule: &QuadratureRule| -> f64 {
            let mut worst: f64 = 0.0;
            for i in 0..=50 {
                // geometric sweep of the spectrum
                let x = lo * (hi / lo).powf(i as f64 / 50.0);
                let rel = (rule.eval_inv_sqrt(x) - 1.0 / x.sqrt()).abs() * x.sqrt();
                worst = worst.max(rel);
            }
            worst
        };
        let e4 = probe(&ciq_quadrature(4, lo, hi).unwrap());
        let e8 = probe(&ciq_quadrature(8, lo, hi).unwrap());
        let e16 = probe(&ciq_quadrature(16, lo, hi).unwrap());
        assert!(e8 < e4 * 0.1, "e4={e4} e8={e8}");
        assert!(e16 < e8 * 0.1, "e8={e8} e16={e16}");
        assert!(e16 < 1e-10, "e16={e16}");
    }

    #[test]
    fn q8_reaches_1e4_even_ill_conditioned() {
        // Paper: Q=8 reaches < 1e-4 relative error for kappa ≈ 1e4.
        let rule = ciq_quadrature(8, 1e-4, 1.0).unwrap();
        for i in 0..=40 {
            let x = 1e-4f64 * (1e4f64).powf(i as f64 / 40.0);
            let rel = (rule.eval_inv_sqrt(x) - 1.0 / x.sqrt()).abs() * x.sqrt();
            assert!(rel < 1e-4, "x={x}: rel={rel}");
        }
    }

    #[test]
    fn weights_and_shifts_positive() {
        for &(lo, hi) in &[(0.1, 1.0), (1e-6, 1.0), (2.0, 1e4)] {
            let rule = ciq_quadrature(8, lo, hi).unwrap();
            assert!(rule.weights.iter().all(|&w| w > 0.0));
            assert!(rule.shifts.iter().all(|&t| t > 0.0));
        }
    }

    #[test]
    fn degenerate_kappa_one() {
        let rule = ciq_quadrature(8, 2.0, 2.0).unwrap();
        let approx = rule.eval_inv_sqrt(2.0);
        assert!((approx - 1.0 / 2.0f64.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(ciq_quadrature(8, -1.0, 1.0).is_err());
        assert!(ciq_quadrature(8, 2.0, 1.0).is_err());
        assert!(ciq_quadrature(0, 1.0, 2.0).is_err());
    }

    #[test]
    fn error_bound_is_monotone_in_kappa() {
        let r1 = ciq_quadrature(8, 1.0, 10.0).unwrap();
        let r2 = ciq_quadrature(8, 1.0, 1e6).unwrap();
        assert!(r1.error_bound() < r2.error_bound());
    }
}

//! Hierarchical timer wheel: O(1) arm and cancel, amortized-O(1) expiry.
//!
//! The wheel is the executor's deadline store. Four levels of 64 slots each
//! cover `64^4` ticks (~28 min at [`crate::exec::Executor::new`]'s 100 µs
//! tick — the wheel itself takes the tick as a parameter); anything farther
//! lands in an overflow list that is re-examined when the top level wraps.
//! A timer at delta `d` ticks lives at level `⌊log64 d⌋`, in the slot its
//! absolute expiry tick hashes to — so arming is a push onto one `Vec` and
//! cancelling is a `swap_remove` through an id → position index, both O(1).
//!
//! [`TimerWheel::advance`] walks the tick cursor forward, firing the level-0
//! slot at every tick and *cascading* a higher level's slot into the levels
//! below whenever the cursor crosses that level's boundary. Entries fire in
//! arm order within a tick (slot `Vec`s preserve insertion order; cancels
//! use `swap_remove` but never reorder *surviving* same-tick entries
//! relative to a fire, because a fire drains the whole slot at once).
//!
//! The wheel is a plain single-threaded data structure: the executor owns
//! it, arms from futures (same thread), and fires from its run loop. Only
//! the `Waker`s stored in entries cross threads (by `Waker`'s own contract).

use std::collections::HashMap;
use std::task::Waker;
use std::time::{Duration, Instant};

/// Slots per level (64 keeps slot math to shifts and masks).
pub const SLOTS: usize = 64;
/// Hierarchy depth.
pub const LEVELS: usize = 4;
const SLOT_BITS: u32 = 6; // log2(SLOTS)

/// Handle to an armed timer, used for O(1) cancellation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TimerId(u64);

struct Entry {
    id: u64,
    expiry_tick: u64,
    waker: Waker,
}

/// Where an armed timer currently lives (kept exact across cascades so
/// cancel can `swap_remove` without scanning).
#[derive(Clone, Copy)]
enum Pos {
    Slot { level: usize, slot: usize, idx: usize },
    Overflow { idx: usize },
}

/// The wheel itself. See the module docs for the level/cascade scheme.
pub struct TimerWheel {
    origin: Instant,
    tick_ns: u64,
    /// The last tick fully processed by [`Self::advance`].
    now_tick: u64,
    levels: Vec<Vec<Vec<Entry>>>,
    overflow: Vec<Entry>,
    index: HashMap<u64, Pos>,
    next_id: u64,
    /// Exact earliest pending expiry tick when `soonest_valid`; recomputed
    /// lazily (one pass over the slots) after the minimum fires or cancels.
    soonest: Option<u64>,
    soonest_valid: bool,
    /// Total timers ever fired (telemetry; cancelled timers never count).
    pub fired_total: u64,
}

impl TimerWheel {
    /// A wheel with the given tick granularity, originated `now`.
    pub fn new(tick: Duration) -> TimerWheel {
        TimerWheel::with_origin(tick, Instant::now())
    }

    /// A wheel with an explicit origin (deterministic tests).
    pub fn with_origin(tick: Duration, origin: Instant) -> TimerWheel {
        let tick_ns = (tick.as_nanos() as u64).max(1);
        TimerWheel {
            origin,
            tick_ns,
            now_tick: 0,
            levels: (0..LEVELS).map(|_| (0..SLOTS).map(|_| Vec::new()).collect()).collect(),
            overflow: Vec::new(),
            index: HashMap::new(),
            next_id: 0,
            soonest: None,
            soonest_valid: true,
            fired_total: 0,
        }
    }

    /// Ticks elapsed from the origin to `t` (saturating at zero for
    /// pre-origin instants).
    fn ticks_at(&self, t: Instant) -> u64 {
        let d = t.saturating_duration_since(self.origin);
        (d.as_nanos() / self.tick_ns as u128).min(u64::MAX as u128) as u64
    }

    fn instant_of_tick(&self, tick: u64) -> Instant {
        self.origin + Duration::from_nanos(tick.saturating_mul(self.tick_ns))
    }

    /// Number of pending (armed, not yet fired or cancelled) timers.
    pub fn pending(&self) -> usize {
        self.index.len()
    }

    /// Arm a timer: `waker` is woken once the wheel advances past
    /// `deadline`. Deadlines at or before the current tick are rounded up
    /// to the next tick (a timer always fires strictly after it is armed).
    pub fn arm(&mut self, deadline: Instant, waker: Waker) -> TimerId {
        let expiry_tick = self.ticks_at(deadline).max(self.now_tick + 1);
        let id = self.next_id;
        self.next_id += 1;
        if self.soonest_valid {
            self.soonest = Some(self.soonest.map_or(expiry_tick, |s| s.min(expiry_tick)));
        }
        self.place(Entry { id, expiry_tick, waker });
        TimerId(id)
    }

    /// Cancel an armed timer. Returns `false` if it already fired or was
    /// already cancelled. O(1): position lookup + `swap_remove`.
    pub fn cancel(&mut self, id: TimerId) -> bool {
        let Some(pos) = self.index.remove(&id.0) else {
            return false;
        };
        let removed = self.remove_at(pos);
        if self.soonest_valid && Some(removed.expiry_tick) == self.soonest {
            // the cached minimum may have just left; recompute on demand
            self.soonest_valid = false;
        }
        true
    }

    /// The exact earliest pending expiry tick (recomputing the lazy cache
    /// with one pass over the slots when the previous minimum left).
    fn soonest_tick(&mut self) -> Option<u64> {
        if self.index.is_empty() {
            return None;
        }
        if !self.soonest_valid {
            let mut min = u64::MAX;
            for level in &self.levels {
                for slot in level {
                    for e in slot {
                        min = min.min(e.expiry_tick);
                    }
                }
            }
            for e in &self.overflow {
                min = min.min(e.expiry_tick);
            }
            self.soonest = Some(min);
            self.soonest_valid = true;
        }
        self.soonest
    }

    /// The instant of the earliest pending deadline, if any. Exact (no
    /// spurious early deadlines): the executor parks precisely until this.
    pub fn next_deadline(&mut self) -> Option<Instant> {
        self.soonest_tick().map(|t| self.instant_of_tick(t))
    }

    /// Advance the cursor to `now`, firing every due timer (waking it and
    /// returning its id, in fire order) and cascading higher levels at
    /// their boundaries.
    pub fn advance(&mut self, now: Instant) -> Vec<TimerId> {
        let target = self.ticks_at(now);
        let mut fired = Vec::new();
        while self.now_tick < target {
            // empty wheel: nothing can fire, jump straight to the target
            let Some(soonest) = self.soonest_tick() else {
                self.now_tick = target;
                break;
            };
            // Leap over the empty stretch up to the next expiry (or the
            // target, whichever is first): after a long park (one far
            // timer, no traffic) walking every elapsed tick would cost
            // O(elapsed/tick). Slot positions are cursor-relative, so the
            // cursor cannot simply jump — every entry is re-placed against
            // the new cursor instead (O(pending), paid once per leap).
            // Short stretches just walk: below about one slot lap the
            // per-tick loop is cheaper than a re-place.
            let leap_to = (soonest - 1).min(target);
            if leap_to > self.now_tick + SLOTS as u64 {
                let entries = self.drain_all();
                self.now_tick = leap_to;
                for e in entries {
                    self.place(e);
                }
            }
            // walk tick-by-tick up to the next fire (or the target)
            let walk_to = soonest.min(target);
            while self.now_tick < walk_to {
                self.now_tick += 1;
                let t = self.now_tick;
                // Drain the overflow list when the top level wraps: every
                // overflow entry was ≥ 64^4 ticks out when armed, so the
                // next wrap always precedes its expiry.
                if t % (SLOTS as u64).pow(LEVELS as u32) == 0 && !self.overflow.is_empty() {
                    let of = std::mem::take(&mut self.overflow);
                    for e in of {
                        self.index.remove(&e.id);
                        self.replace_or_fire(e, &mut fired);
                    }
                }
                // Cascade boundary-crossing levels, highest first, so an
                // entry dropping several levels is re-placed before the
                // level below takes its own slot this tick.
                for level in (1..LEVELS).rev() {
                    if t % (SLOTS as u64).pow(level as u32) == 0 {
                        let slot = ((t >> (SLOT_BITS * level as u32)) % SLOTS as u64) as usize;
                        let entries = std::mem::take(&mut self.levels[level][slot]);
                        for e in entries {
                            self.index.remove(&e.id);
                            self.replace_or_fire(e, &mut fired);
                        }
                    }
                }
                // Fire the level-0 slot for this tick. Every entry here
                // expires exactly now (level-0 residency implies expiry
                // within the current lap), but stay defensive about a
                // same-slot future lap.
                let slot0 = (t % SLOTS as u64) as usize;
                if !self.levels[0][slot0].is_empty() {
                    let entries = std::mem::take(&mut self.levels[0][slot0]);
                    for e in entries {
                        self.index.remove(&e.id);
                        self.replace_or_fire(e, &mut fired);
                    }
                }
            }
        }
        fired
    }

    /// Pull every entry out of the wheel (slots + overflow), clearing the
    /// position index — the leap in [`Self::advance`] re-places them
    /// against the moved cursor.
    fn drain_all(&mut self) -> Vec<Entry> {
        let mut entries = Vec::with_capacity(self.index.len());
        for level in &mut self.levels {
            for slot in level {
                entries.append(slot);
            }
        }
        entries.append(&mut self.overflow);
        self.index.clear();
        entries
    }

    /// Re-place an entry relative to the current tick, or fire it if due.
    /// A fire invalidates the cached minimum (the fired entry may have been
    /// it); the next `soonest_tick` recomputes.
    fn replace_or_fire(&mut self, e: Entry, fired: &mut Vec<TimerId>) {
        if e.expiry_tick <= self.now_tick {
            self.fired_total += 1;
            self.soonest_valid = false;
            fired.push(TimerId(e.id));
            e.waker.wake();
        } else {
            self.place(e);
        }
    }

    /// Insert into the right level/slot for its delta, recording the
    /// position in the id index.
    fn place(&mut self, e: Entry) {
        let delta = e.expiry_tick - self.now_tick;
        let id = e.id;
        let horizon = (SLOTS as u64).pow(LEVELS as u32);
        if delta >= horizon {
            self.overflow.push(e);
            self.index.insert(id, Pos::Overflow { idx: self.overflow.len() - 1 });
            return;
        }
        let mut level = 0usize;
        let mut span = SLOTS as u64;
        while delta >= span {
            level += 1;
            span *= SLOTS as u64;
        }
        let slot = ((e.expiry_tick >> (SLOT_BITS * level as u32)) % SLOTS as u64) as usize;
        self.levels[level][slot].push(e);
        let idx = self.levels[level][slot].len() - 1;
        self.index.insert(id, Pos::Slot { level, slot, idx });
    }

    /// Remove the entry at `pos` (its index entry is already gone), fixing
    /// up the index of whichever entry `swap_remove` moved into its place.
    fn remove_at(&mut self, pos: Pos) -> Entry {
        match pos {
            Pos::Slot { level, slot, idx } => {
                let v = &mut self.levels[level][slot];
                let e = v.swap_remove(idx);
                if idx < v.len() {
                    let moved = v[idx].id;
                    self.index.insert(moved, Pos::Slot { level, slot, idx });
                }
                e
            }
            Pos::Overflow { idx } => {
                let e = self.overflow.swap_remove(idx);
                if idx < self.overflow.len() {
                    let moved = self.overflow[idx].id;
                    self.index.insert(moved, Pos::Overflow { idx });
                }
                e
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::task::Wake;

    /// Waker that counts its wakes.
    struct CountingWake(AtomicUsize);
    impl Wake for CountingWake {
        fn wake(self: Arc<Self>) {
            self.0.fetch_add(1, Ordering::SeqCst);
        }
        fn wake_by_ref(self: &Arc<Self>) {
            self.0.fetch_add(1, Ordering::SeqCst);
        }
    }

    fn counter() -> (Arc<CountingWake>, Waker) {
        let c = Arc::new(CountingWake(AtomicUsize::new(0)));
        (c.clone(), Waker::from(c))
    }

    fn wheel() -> (TimerWheel, Instant) {
        let origin = Instant::now();
        (TimerWheel::with_origin(Duration::from_millis(1), origin), origin)
    }

    fn at(origin: Instant, ticks: u64) -> Instant {
        origin + Duration::from_millis(ticks)
    }

    #[test]
    fn arm_fire_and_pending_accounting() {
        let (mut w, o) = wheel();
        let (c, wk) = counter();
        w.arm(at(o, 5), wk);
        assert_eq!(w.pending(), 1);
        assert!(w.advance(at(o, 4)).is_empty(), "fired before deadline");
        assert_eq!(c.0.load(Ordering::SeqCst), 0);
        let fired = w.advance(at(o, 5));
        assert_eq!(fired.len(), 1);
        assert_eq!(c.0.load(Ordering::SeqCst), 1);
        assert_eq!(w.pending(), 0);
        assert!(w.next_deadline().is_none());
    }

    #[test]
    fn cancel_prevents_fire_and_is_o1_bookkept() {
        let (mut w, o) = wheel();
        let (c1, wk1) = counter();
        let (c2, wk2) = counter();
        let t1 = w.arm(at(o, 10), wk1);
        let t2 = w.arm(at(o, 10), wk2);
        assert!(w.cancel(t1));
        assert!(!w.cancel(t1), "double cancel must be a no-op");
        assert_eq!(w.pending(), 1);
        let fired = w.advance(at(o, 20));
        assert_eq!(fired, vec![t2]);
        assert_eq!(c1.0.load(Ordering::SeqCst), 0, "cancelled timer fired");
        assert_eq!(c2.0.load(Ordering::SeqCst), 1);
        assert!(!w.cancel(t2), "cancelling a fired timer must return false");
    }

    #[test]
    fn simultaneous_expiry_fires_in_arm_order() {
        let (mut w, o) = wheel();
        let mut ids = Vec::new();
        for _ in 0..5 {
            let (_, wk) = counter();
            ids.push(w.arm(at(o, 7), wk));
        }
        let fired = w.advance(at(o, 7));
        assert_eq!(fired, ids, "same-tick timers must fire in arm order");
    }

    #[test]
    fn cascade_across_levels() {
        let (mut w, o) = wheel();
        // one timer per level: deltas 3 (L0), 100 (L1), 5000 (L2), 300_000 (L3)
        let deadlines = [3u64, 100, 5000, 300_000];
        let counters: Vec<_> = deadlines
            .iter()
            .map(|&d| {
                let (c, wk) = counter();
                (d, c, w.arm(at(o, d), wk))
            })
            .collect();
        assert_eq!(w.pending(), 4);
        // walk time forward in uneven jumps crossing every cascade boundary
        let mut now = 0u64;
        for &(d, ref c, id) in &counters {
            while now < d {
                now = (now + 917).min(d);
                let fired = w.advance(at(o, now));
                if now >= d {
                    assert!(fired.contains(&id), "timer at {d} did not fire by {now}");
                }
            }
            assert_eq!(c.0.load(Ordering::SeqCst), 1, "timer at {d} wake count");
        }
        assert_eq!(w.pending(), 0);
        assert_eq!(w.fired_total, 4);
    }

    #[test]
    fn far_future_deadline_goes_to_overflow_and_survives_cancel() {
        let (mut w, o) = wheel();
        // beyond the 64^4-tick horizon
        let horizon = 64u64 * 64 * 64 * 64;
        let (c, wk) = counter();
        let far = w.arm(at(o, horizon + 17), wk);
        let (_, wk2) = counter();
        let far2 = w.arm(at(o, horizon * 2), wk2);
        assert_eq!(w.pending(), 2);
        // next_deadline is exact even for overflow residents
        assert_eq!(w.next_deadline(), Some(at(o, horizon + 17)));
        assert!(w.cancel(far2));
        assert_eq!(w.pending(), 1);
        // nothing fires while the cursor is far away
        assert!(w.advance(at(o, 1000)).is_empty());
        assert_eq!(c.0.load(Ordering::SeqCst), 0);
        assert!(w.cancel(far));
        assert_eq!(w.pending(), 0);
    }

    #[test]
    fn next_deadline_recomputes_after_min_leaves() {
        let (mut w, o) = wheel();
        let (_, wk1) = counter();
        let (_, wk2) = counter();
        let first = w.arm(at(o, 4), wk1);
        w.arm(at(o, 9), wk2);
        assert_eq!(w.next_deadline(), Some(at(o, 4)));
        assert!(w.cancel(first));
        assert_eq!(w.next_deadline(), Some(at(o, 9)), "min must recompute after cancel");
        let fired = w.advance(at(o, 9));
        assert_eq!(fired.len(), 1);
        assert!(w.next_deadline().is_none());
    }

    #[test]
    fn past_deadline_fires_on_next_advance() {
        let (mut w, o) = wheel();
        w.advance(at(o, 50));
        let (c, wk) = counter();
        // deadline already in the past: rounds up to the next tick
        w.arm(at(o, 10), wk);
        assert_eq!(w.next_deadline(), Some(at(o, 51)));
        let fired = w.advance(at(o, 51));
        assert_eq!(fired.len(), 1);
        assert_eq!(c.0.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn long_gap_leaps_without_walking_or_losing_timers() {
        let (mut w, o) = wheel();
        let (c1, wk1) = counter();
        let (c2, wk2) = counter();
        let near = w.arm(at(o, 500), wk1);
        let far = w.arm(at(o, 200_000), wk2);
        // one giant advance: both must fire, in deadline order
        let fired = w.advance(at(o, 200_000));
        assert_eq!(fired, vec![near, far]);
        assert_eq!(c1.0.load(Ordering::SeqCst), 1);
        assert_eq!(c2.0.load(Ordering::SeqCst), 1);
        assert_eq!(w.pending(), 0);

        // a leap *below* the earliest expiry re-places entries but fires
        // nothing, and the deadline stays exact afterwards
        let (c3, wk3) = counter();
        let id = w.arm(at(o, 500_000), wk3);
        assert!(w.advance(at(o, 450_000)).is_empty());
        assert_eq!(w.next_deadline(), Some(at(o, 500_000)));
        assert_eq!(w.advance(at(o, 500_000)), vec![id]);
        assert_eq!(c3.0.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn timer_ids_are_never_recycled() {
        // Ids are monotonic for the wheel's lifetime: a handle that outlives
        // its timer (fired or cancelled) can never alias a newer timer.
        let (mut w, o) = wheel();
        let (_, wk) = counter();
        let a = w.arm(at(o, 2), wk);
        assert_eq!(w.advance(at(o, 2)).len(), 1);
        let (_, wk) = counter();
        let b = w.arm(at(o, 4), wk);
        assert_ne!(a, b, "fired id recycled");
        assert!(w.cancel(b));
        let (_, wk) = counter();
        let c = w.arm(at(o, 6), wk);
        assert_ne!(b, c, "cancelled id recycled");
        assert_ne!(a, c);
    }

    #[test]
    fn stale_id_cannot_cancel_after_swap_remove_fixup() {
        // cancel() uses swap_remove + index fixup; a stale handle held
        // across that shuffle must stay dead and the moved survivor must
        // stay cancellable/fireable under its own id.
        let (mut w, o) = wheel();
        let (_, wk1) = counter();
        let (c2, wk2) = counter();
        let (c3, wk3) = counter();
        let t1 = w.arm(at(o, 8), wk1);
        let t2 = w.arm(at(o, 8), wk2);
        let t3 = w.arm(at(o, 8), wk3); // same slot as t1/t2
        assert!(w.cancel(t1)); // swap_remove moves t3 into t1's index
        assert!(!w.cancel(t1), "stale id revived after fixup");
        assert!(w.cancel(t3), "moved entry lost its index");
        assert_eq!(c3.0.load(Ordering::SeqCst), 0);
        assert_eq!(w.advance(at(o, 8)), vec![t2]);
        assert_eq!(c2.0.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn stale_id_cannot_cancel_newer_timer_in_same_slot() {
        // After t1 fires, a new timer occupying the same slot position must
        // be untouchable through the old handle.
        let (mut w, o) = wheel();
        let (_, wk) = counter();
        let t1 = w.arm(at(o, 3), wk);
        assert_eq!(w.advance(at(o, 3)), vec![t1]);
        let (c, wk) = counter();
        // same level-0 slot one lap later (3 + 64 ticks)
        let t2 = w.arm(at(o, 3 + SLOTS as u64), wk);
        assert!(!w.cancel(t1), "stale id cancelled a successor");
        assert_eq!(w.pending(), 1);
        assert_eq!(w.advance(at(o, 3 + SLOTS as u64)), vec![t2]);
        assert_eq!(c.0.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn empty_wheel_fast_forwards() {
        let (mut w, o) = wheel();
        assert!(w.advance(at(o, 10_000_000)).is_empty());
        let (c, wk) = counter();
        w.arm(at(o, 10_000_005), wk);
        let fired = w.advance(at(o, 10_000_005));
        assert_eq!(fired.len(), 1);
        assert_eq!(c.0.load(Ordering::SeqCst), 1);
    }
}

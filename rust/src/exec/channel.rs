//! An MPSC channel whose receive side is a `Future`: the executor's event
//! source.
//!
//! `Sender::send` (callable from any thread) pushes the value and wakes the
//! waker the receiver registered on its last pending poll — which, for a
//! task on [`crate::exec::Executor`], unparks the executor thread. This is
//! what lets the coordinator's intake task *sleep* between arrivals instead
//! of bounding a `recv_timeout` poll loop: an idle channel generates zero
//! wakeups.
//!
//! Single consumer: one waker slot, owned by whichever `recv` future polled
//! last. Dropping the last `Sender` closes the channel; `recv` then drains
//! the queue and resolves `None`. Dropping the `Receiver` makes every
//! subsequent `send` return the value to the caller as an error.

use crate::util::sync::Mutex;
use std::collections::VecDeque;
use std::future::Future;
use std::pin::Pin;
use std::sync::Arc;
use std::task::{Context, Poll, Waker};

struct ChanState<T> {
    queue: VecDeque<T>,
    waker: Option<Waker>,
    senders: usize,
    rx_alive: bool,
}

struct Shared<T> {
    state: Mutex<ChanState<T>>,
}

/// Create a channel. The `Sender` is cloneable and `Send`; the `Receiver`
/// is single-consumer.
pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
    let shared = Arc::new(Shared {
        state: Mutex::new(ChanState {
            queue: VecDeque::new(),
            waker: None,
            senders: 1,
            rx_alive: true,
        }),
    });
    (Sender { shared: shared.clone() }, Receiver { shared })
}

/// Producer half. Cloneable; usable from any thread.
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

impl<T> Sender<T> {
    /// Push a value and wake the receiver. Returns the value back if the
    /// receiver is gone.
    pub fn send(&self, value: T) -> Result<(), T> {
        let waker = {
            let mut st = self.shared.state.lock().unwrap();
            if !st.rx_alive {
                return Err(value);
            }
            st.queue.push_back(value);
            st.waker.take()
        };
        // wake outside the lock: the waker may grab the executor's own locks
        if let Some(w) = waker {
            w.wake();
        }
        Ok(())
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.shared.state.lock().unwrap().senders += 1;
        Sender { shared: self.shared.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let waker = {
            let mut st = self.shared.state.lock().unwrap();
            st.senders -= 1;
            if st.senders == 0 {
                // closed: wake the receiver so a pending recv resolves None.
                // Dropping this wake (mutation M1 in rust/tests/model_exec.rs)
                // strands a receiver that registered its waker before the
                // last sender dropped — the model checker finds that
                // interleaving as a deadlock.
                st.waker.take()
            } else {
                None
            }
        };
        if let Some(w) = waker {
            w.wake();
        }
    }
}

/// Consumer half.
pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

impl<T> Receiver<T> {
    /// A future resolving to the next value, or `None` once every sender
    /// has dropped and the queue is drained.
    pub fn recv(&mut self) -> Recv<'_, T> {
        Recv { rx: self }
    }

    /// Non-blocking pop (used by drains and tests).
    pub fn try_recv(&mut self) -> Option<T> {
        self.shared.state.lock().unwrap().queue.pop_front()
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().rx_alive = false;
    }
}

/// Future returned by [`Receiver::recv`].
pub struct Recv<'a, T> {
    rx: &'a mut Receiver<T>,
}

impl<T> Future for Recv<'_, T> {
    type Output = Option<T>;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Self::Output> {
        let this = self.get_mut();
        let mut st = this.rx.shared.state.lock().unwrap();
        if let Some(v) = st.queue.pop_front() {
            return Poll::Ready(Some(v));
        }
        if st.senders == 0 {
            return Poll::Ready(None);
        }
        st.waker = Some(cx.waker().clone());
        Poll::Pending
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Executor;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn send_recv_in_order_and_close_resolves_none() {
        let (tx, mut rx) = channel::<u32>();
        let exec = Executor::new();
        let got: Rc<RefCell<Vec<u32>>> = Rc::new(RefCell::new(Vec::new()));
        let got2 = got.clone();
        exec.handle().spawn(async move {
            while let Some(v) = rx.recv().await {
                got2.borrow_mut().push(v);
            }
        });
        for v in [1u32, 2, 3] {
            tx.send(v).unwrap();
        }
        drop(tx);
        exec.run();
        assert_eq!(*got.borrow(), vec![1, 2, 3]);
    }

    #[test]
    fn cross_thread_send_wakes_parked_executor() {
        let (tx, mut rx) = channel::<u64>();
        let exec = Executor::new();
        let got: Rc<RefCell<Vec<u64>>> = Rc::new(RefCell::new(Vec::new()));
        let got2 = got.clone();
        exec.handle().spawn(async move {
            while let Some(v) = rx.recv().await {
                got2.borrow_mut().push(v);
            }
        });
        // sender thread fires after the executor has certainly parked
        let sender = std::thread::spawn(move || {
            for v in 0..8u64 {
                std::thread::sleep(std::time::Duration::from_millis(2));
                tx.send(v).unwrap();
            }
            // tx drops here: executor run loop terminates
        });
        exec.run();
        sender.join().unwrap();
        assert_eq!(*got.borrow(), (0..8).collect::<Vec<u64>>());
    }

    #[test]
    fn send_after_receiver_drop_errors_with_value() {
        let (tx, rx) = channel::<String>();
        drop(rx);
        match tx.send("orphan".to_string()) {
            Err(v) => assert_eq!(v, "orphan"),
            Ok(()) => panic!("send to a dropped receiver must fail"),
        }
    }
}

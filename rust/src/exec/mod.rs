//! `exec` — a dependency-free, single-threaded async executor with a
//! hierarchical timer wheel.
//!
//! The serving layer's problem is *waiting*, not computing: the coordinator
//! multiplexes many shard deadlines and channel arrivals, and a thread+mpsc
//! dispatcher pays a wakeup per `recv_timeout` tick and an O(shards)
//! deadline scan per event. This module gives one thread the machinery to
//! wait on all of it at once:
//!
//! * **Tasks** are plain `Future<Output = ()>`s (no `Send` bound — the
//!   executor is single-threaded by design), stored as `Pin<Box<dyn
//!   Future>>` and polled through std's `Waker` protocol via
//!   [`std::task::Wake`].
//! * **Wakes are cross-thread**: a waker pushes the task id onto a shared
//!   ready queue and notifies the executor's condvar, so mpsc senders on
//!   other threads ([`channel`]) unpark the executor directly. A per-task
//!   `queued` flag dedupes redundant wakes.
//! * **Deadlines** live in a [`timer::TimerWheel`] (O(1) arm/cancel). The
//!   run loop parks *exactly* until the earliest pending deadline — or
//!   indefinitely when none is armed. An idle executor therefore performs
//!   **zero** wakeups: no tick thread, no poll interval.
//!
//! Compute does not belong here: CPU-bound work (batch solves, context
//! builds) goes to a worker pool ([`crate::util::threadpool::TaskPool`]);
//! the executor owns the waiting. See `rust/DESIGN.md` §3.

pub mod channel;
pub mod timer;

pub use timer::{TimerId, TimerWheel};

use crate::util::sync::{AtomicBool, AtomicU64, Condvar, Mutex, Ordering};
use std::cell::{Cell, RefCell};
use std::collections::{HashMap, VecDeque};
use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;
use std::sync::Arc;
use std::task::{Context, Poll, Wake, Waker};
use std::time::{Duration, Instant};

/// Executor telemetry (process-lifetime atomics, readable from any thread).
#[derive(Default)]
pub struct ExecStats {
    /// Times the run loop parked with nothing ready.
    pub parks: AtomicU64,
    /// Times a parked run loop resumed (timer deadline or external wake).
    pub wakeups: AtomicU64,
    /// Task polls performed.
    pub polls: AtomicU64,
    /// Timers fired by the wheel.
    pub timer_fires: AtomicU64,
}

impl ExecStats {
    /// Plain-data copy of the counters for [`crate::obs::MetricsSnapshot`].
    pub fn snapshot(&self) -> crate::obs::ExecSnapshot {
        // ordering: Relaxed — monitoring snapshot of independent counters;
        // no cross-counter consistency is implied.
        crate::obs::ExecSnapshot {
            parks: self.parks.load(Ordering::Relaxed),
            wakeups: self.wakeups.load(Ordering::Relaxed),
            polls: self.polls.load(Ordering::Relaxed),
            timer_fires: self.timer_fires.load(Ordering::Relaxed),
        }
    }
}

/// Cross-thread wake state: the ready queue plus the condvar the executor
/// thread parks on.
struct ExecShared {
    ready: Mutex<VecDeque<u64>>,
    cv: Condvar,
    stats: Arc<ExecStats>,
}

/// One task's waker: pushes the task id onto the ready queue (deduped by
/// `queued`) and unparks the executor.
struct TaskWaker {
    id: u64,
    queued: AtomicBool,
    shared: Arc<ExecShared>,
}

impl Wake for TaskWaker {
    fn wake(self: Arc<Self>) {
        self.wake_by_ref();
    }
    fn wake_by_ref(self: &Arc<Self>) {
        // ordering: AcqRel — the swap must both *acquire* the task state
        // written by the run loop before it cleared `queued` (so this wake
        // sees a fully-published pending task) and *release* our claim so
        // the run loop's next clear synchronizes with it. Relaxed here could
        // let two wakers both observe `false` only in theory on the same
        // task id — the queue push below is lock-serialized — but the dedup
        // contract ("at most one queue entry per cleared flag") is what the
        // model test `exec_queued_flag_dedup` pins down.
        if !self.queued.swap(true, Ordering::AcqRel) {
            self.shared.ready.lock().unwrap().push_back(self.id);
            self.shared.cv.notify_one();
        }
    }
}

struct Task {
    fut: Pin<Box<dyn Future<Output = ()>>>,
    waker: Arc<TaskWaker>,
}

struct Inner {
    shared: Arc<ExecShared>,
    tasks: RefCell<HashMap<u64, Task>>,
    next_task: Cell<u64>,
    wheel: RefCell<TimerWheel>,
}

/// The executor. Create on the thread that will run it; hand [`Handle`]s
/// to the futures it drives.
pub struct Executor {
    inner: Rc<Inner>,
}

/// Cloneable, non-`Send` handle for spawning tasks and arming timers from
/// inside tasks.
#[derive(Clone)]
pub struct Handle {
    inner: Rc<Inner>,
}

impl Default for Executor {
    fn default() -> Self {
        Executor::new()
    }
}

impl Executor {
    /// An executor with the default 100 µs timer tick (deadline error is at
    /// most one tick; the wheel only walks ticks while deadlines are
    /// pending, so a fine tick costs nothing at idle).
    pub fn new() -> Executor {
        Executor::with_tick(Duration::from_micros(100))
    }

    /// An executor with an explicit timer-wheel tick.
    pub fn with_tick(tick: Duration) -> Executor {
        Executor {
            inner: Rc::new(Inner {
                shared: Arc::new(ExecShared {
                    ready: Mutex::new(VecDeque::new()),
                    cv: Condvar::new(),
                    stats: Arc::new(ExecStats::default()),
                }),
                tasks: RefCell::new(HashMap::new()),
                next_task: Cell::new(0),
                wheel: RefCell::new(TimerWheel::new(tick)),
            }),
        }
    }

    pub fn handle(&self) -> Handle {
        Handle { inner: self.inner.clone() }
    }

    /// Executor telemetry; the `Arc` may outlive the executor.
    pub fn stats(&self) -> Arc<ExecStats> {
        self.inner.shared.stats.clone()
    }

    /// Run until every spawned task has completed.
    ///
    /// The loop: drain the ready queue (polling each task once), fire due
    /// timers, and — only when nothing is ready and nothing fired — park
    /// until the wheel's next deadline or an external wake. No deadline and
    /// nothing ready means an *indefinite* park: zero idle wakeups.
    pub fn run(&self) {
        let inner = &self.inner;
        loop {
            // 1. drain ready tasks
            loop {
                let id = inner.shared.ready.lock().unwrap().pop_front();
                let Some(id) = id else { break };
                // remove before polling: a task that spawns (or is woken)
                // mid-poll must not alias the tasks map borrow
                let Some(mut task) = inner.tasks.borrow_mut().remove(&id) else {
                    continue; // completed earlier; stale wake
                };
                // clear before the poll so a wake *during* the poll re-queues
                // ordering: Release — pairs with the AcqRel swap in
                // `wake_by_ref`: everything this thread did to the task
                // before clearing is visible to the waker that wins the next
                // swap. Clearing *after* the poll instead would open a lost-
                // wake window (wake lands mid-poll, sees `queued == true`,
                // skips the push, flag is then cleared: task sleeps forever)
                // — caught by model mutation M2 in rust/tests/model_exec.rs.
                task.waker.queued.store(false, Ordering::Release);
                let waker = Waker::from(task.waker.clone());
                let mut cx = Context::from_waker(&waker);
                // ordering: Relaxed — monotonic telemetry counter, no reader
                // infers cross-thread state from it.
                inner.shared.stats.polls.fetch_add(1, Ordering::Relaxed);
                match task.fut.as_mut().poll(&mut cx) {
                    Poll::Ready(()) => {}
                    Poll::Pending => {
                        inner.tasks.borrow_mut().insert(id, task);
                    }
                }
            }
            // 2. fire due timers (their wakes land on the ready queue)
            // clock: the wheel is advanced to real time once per loop turn.
            let fired = inner.wheel.borrow_mut().advance(Instant::now());
            if !fired.is_empty() {
                // ordering: Relaxed — telemetry counter.
                inner.shared.stats.timer_fires.fetch_add(fired.len() as u64, Ordering::Relaxed);
                continue;
            }
            if inner.tasks.borrow().is_empty() {
                return;
            }
            // 3. park until the earliest deadline or an external wake
            let deadline = inner.wheel.borrow_mut().next_deadline();
            let ready = inner.shared.ready.lock().unwrap();
            if !ready.is_empty() {
                continue; // a wake slipped in between drain and park
            }
            // ordering: Relaxed — telemetry counter.
            inner.shared.stats.parks.fetch_add(1, Ordering::Relaxed);
            match deadline {
                Some(d) => {
                    // clock: park timeout = remaining real time to deadline.
                    let timeout = d.saturating_duration_since(Instant::now());
                    let (guard, _) = inner.shared.cv.wait_timeout(ready, timeout).unwrap();
                    drop(guard);
                }
                None => {
                    let guard = inner.shared.cv.wait(ready).unwrap();
                    drop(guard);
                }
            }
            // ordering: Relaxed — telemetry counter.
            inner.shared.stats.wakeups.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Spawn `fut`, run the executor to quiescence, and return `fut`'s
    /// output (tests / simple drivers).
    pub fn block_on<T: 'static>(&self, fut: impl Future<Output = T> + 'static) -> T {
        let out: Rc<RefCell<Option<T>>> = Rc::new(RefCell::new(None));
        let out2 = out.clone();
        self.handle().spawn(async move {
            *out2.borrow_mut() = Some(fut.await);
        });
        self.run();
        let v = out.borrow_mut().take();
        v.expect("block_on future did not complete")
    }
}

impl Handle {
    /// Spawn a task. No `Send` bound: the executor is single-threaded.
    pub fn spawn(&self, fut: impl Future<Output = ()> + 'static) {
        let id = self.inner.next_task.get();
        self.inner.next_task.set(id + 1);
        let waker = Arc::new(TaskWaker {
            id,
            // born queued: we schedule it ourselves right below
            queued: AtomicBool::new(true),
            shared: self.inner.shared.clone(),
        });
        self.inner.tasks.borrow_mut().insert(id, Task { fut: Box::pin(fut), waker });
        self.inner.shared.ready.lock().unwrap().push_back(id);
        self.inner.shared.cv.notify_one();
    }

    /// A future that resolves `true` after `d` elapses (no cancel handle).
    pub fn sleep(&self, d: Duration) -> Sleep {
        // clock: relative sleep is anchored at the call instant.
        self.timer_at(Instant::now() + d).0
    }

    /// Arm a timer for `deadline` **now** (before any poll), returning the
    /// sleep future and an O(1) cancel handle. The future resolves `true`
    /// when the timer fires, `false` when cancelled.
    pub fn timer_at(&self, deadline: Instant) -> (Sleep, TimerCancel) {
        let state = Arc::new(SleepShared { inner: Mutex::new(SleepInner { done: None, waker: None }) });
        let wheel_waker = Waker::from(Arc::new(SleepWake(state.clone())));
        let id = self.inner.wheel.borrow_mut().arm(deadline, wheel_waker);
        (
            Sleep { state: state.clone() },
            TimerCancel { id, state, inner: self.inner.clone() },
        )
    }

    /// Timers currently armed (tests).
    pub fn pending_timers(&self) -> usize {
        self.inner.wheel.borrow().pending()
    }
}

struct SleepInner {
    /// `Some(true)` fired, `Some(false)` cancelled, `None` pending.
    done: Option<bool>,
    waker: Option<Waker>,
}

struct SleepShared {
    inner: Mutex<SleepInner>,
}

impl SleepShared {
    fn finish(&self, fired: bool) {
        let waker = {
            let mut st = self.inner.lock().unwrap();
            if st.done.is_some() {
                return; // fire/cancel race: first outcome wins
            }
            st.done = Some(fired);
            st.waker.take()
        };
        if let Some(w) = waker {
            w.wake();
        }
    }
}

/// The waker the wheel holds for a [`Sleep`]: marks it fired, then wakes
/// the task awaiting it.
struct SleepWake(Arc<SleepShared>);

impl Wake for SleepWake {
    fn wake(self: Arc<Self>) {
        self.0.finish(true);
    }
    fn wake_by_ref(self: &Arc<Self>) {
        self.0.finish(true);
    }
}

/// Future from [`Handle::sleep`] / [`Handle::timer_at`]; resolves `true`
/// on fire, `false` on cancel.
pub struct Sleep {
    state: Arc<SleepShared>,
}

impl Future for Sleep {
    type Output = bool;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<bool> {
        let mut st = self.state.inner.lock().unwrap();
        if let Some(fired) = st.done {
            return Poll::Ready(fired);
        }
        st.waker = Some(cx.waker().clone());
        Poll::Pending
    }
}

/// O(1) cancel handle for an armed timer; resolves its [`Sleep`] with
/// `false`. Single-threaded like the executor it points into.
pub struct TimerCancel {
    id: TimerId,
    state: Arc<SleepShared>,
    inner: Rc<Inner>,
}

impl TimerCancel {
    /// Cancel the timer. Returns whether it was still pending (false if it
    /// already fired or was already cancelled). Either way the `Sleep`
    /// future is resolved — an awaiting task never hangs on a cancelled
    /// timer.
    pub fn cancel(self) -> bool {
        let was_pending = self.inner.wheel.borrow_mut().cancel(self.id);
        self.state.finish(false);
        was_pending
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tasks_run_and_spawn_nested() {
        let exec = Executor::new();
        let count = Rc::new(Cell::new(0u32));
        let (c1, h) = (count.clone(), exec.handle());
        exec.handle().spawn(async move {
            c1.set(c1.get() + 1);
            let c2 = c1.clone();
            h.spawn(async move {
                c2.set(c2.get() + 10);
            });
        });
        exec.run();
        assert_eq!(count.get(), 11);
    }

    #[test]
    fn sleeps_complete_in_deadline_order() {
        let exec = Executor::new();
        let order: Rc<RefCell<Vec<u8>>> = Rc::new(RefCell::new(Vec::new()));
        let h = exec.handle();
        for (tag, ms) in [(3u8, 30u64), (1, 5), (2, 15)] {
            let o = order.clone();
            let sleep = h.sleep(Duration::from_millis(ms));
            h.spawn(async move {
                assert!(sleep.await, "uncancelled sleep must fire");
                o.borrow_mut().push(tag);
            });
        }
        exec.run();
        assert_eq!(*order.borrow(), vec![1, 2, 3]);
        assert_eq!(exec.stats().timer_fires.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn cancelled_timer_resolves_false_without_firing() {
        let exec = Executor::new();
        let h = exec.handle();
        let (sleep, cancel) = h.timer_at(Instant::now() + Duration::from_secs(3600));
        let outcome = Rc::new(Cell::new(None));
        let o2 = outcome.clone();
        h.spawn(async move {
            o2.set(Some(sleep.await));
        });
        let h2 = h.clone();
        h.spawn(async move {
            // let the sleeper register first, then cancel from another task
            let brief = h2.sleep(Duration::from_millis(2));
            brief.await;
            assert!(cancel.cancel(), "timer should still be pending");
            assert_eq!(h2.pending_timers(), 0);
        });
        // completes immediately rather than hanging for an hour
        exec.run();
        assert_eq!(outcome.get(), Some(false));
        assert_eq!(exec.stats().timer_fires.load(Ordering::SeqCst), 1); // only the brief sleep
    }

    #[test]
    fn block_on_returns_value() {
        let exec = Executor::new();
        let h = exec.handle();
        let v = exec.block_on(async move {
            h.sleep(Duration::from_millis(1)).await;
            42u64
        });
        assert_eq!(v, 42);
    }

    #[test]
    fn idle_executor_parks_without_wakeups() {
        // an executor whose only task waits on a channel parks indefinitely:
        // no timer fires, no polls beyond the initial one
        let (tx, mut rx) = crate::exec::channel::channel::<u8>();
        let exec = Executor::new();
        let stats = exec.stats();
        exec.handle().spawn(async move {
            while rx.recv().await.is_some() {}
        });
        let sender = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            drop(tx); // close: lets run() terminate
        });
        exec.run();
        sender.join().unwrap();
        assert_eq!(
            stats.timer_fires.load(Ordering::SeqCst),
            0,
            "idle executor fired a timer"
        );
        // initial poll + the close wake: nothing in between
        assert!(
            stats.polls.load(Ordering::SeqCst) <= 2,
            "idle executor polled more than spawn + close"
        );
    }

    #[test]
    fn cancel_racing_fire_at_same_tick_first_outcome_wins() {
        // Two timers armed for the *same* deadline land in the same wheel
        // tick and both fire in one `advance` batch, before either awaiting
        // task gets polled. Task B (whose timer was armed first, so B's task
        // is woken first) then cancels A's handle — but A's timer already
        // fired, so the cancel must lose: `SleepShared::finish`'s
        // first-outcome-wins guard keeps A's resolved value `true`.
        // Removing that guard (mutation M4) flips `a_out` to `false`.
        let exec = Executor::with_tick(Duration::from_millis(1));
        let h = exec.handle();
        let deadline = Instant::now() + Duration::from_millis(10);
        let (sleep_b, _cancel_b) = h.timer_at(deadline); // armed first → fires first
        let (sleep_a, cancel_a) = h.timer_at(deadline);
        let a_out = Rc::new(Cell::new(None));
        let (a2, cancel_won) = (a_out.clone(), Rc::new(Cell::new(None)));
        let c2 = cancel_won.clone();
        h.spawn(async move {
            a2.set(Some(sleep_a.await));
        });
        h.spawn(async move {
            assert!(sleep_b.await, "b's own timer fired");
            c2.set(Some(cancel_a.cancel()));
        });
        exec.run();
        assert_eq!(cancel_won.get(), Some(false), "cancel raced an already-fired timer");
        assert_eq!(a_out.get(), Some(true), "first outcome (fire) must win the race");
    }

    #[test]
    fn cancel_before_fire_wins_and_timer_never_fires() {
        // The mirror image: cancel lands while the timer is genuinely
        // pending; the later deadline must not fire it anyway.
        let exec = Executor::with_tick(Duration::from_millis(1));
        let h = exec.handle();
        let (sleep, cancel) = h.timer_at(Instant::now() + Duration::from_millis(5));
        let out = Rc::new(Cell::new(None));
        let o2 = out.clone();
        h.spawn(async move {
            o2.set(Some(sleep.await));
        });
        let h2 = h.clone();
        h.spawn(async move {
            assert!(cancel.cancel(), "timer still pending");
            // outlive the cancelled deadline to prove it stays dead
            h2.sleep(Duration::from_millis(20)).await;
        });
        let stats = exec.stats();
        exec.run();
        assert_eq!(out.get(), Some(false));
        assert_eq!(stats.timer_fires.load(Ordering::SeqCst), 1, "only the guard sleep fires");
    }

    #[test]
    fn stale_incarnation_deadline_is_ignored() {
        // The coordinator pattern: a deadline task snapshots a shard's
        // generation tag when armed and must no-op if the shard was rebuilt
        // (generation bumped) before the deadline fired. Modeled here at the
        // executor level with an Rc'd generation cell.
        let exec = Executor::with_tick(Duration::from_millis(1));
        let h = exec.handle();
        let generation = Rc::new(Cell::new(1u64));
        let flushes = Rc::new(Cell::new(0u32));
        for _ in 0..2 {
            // two rounds: one stale, one current
            let armed_gen = generation.get();
            let (g2, f2) = (generation.clone(), flushes.clone());
            let sleep = h.sleep(Duration::from_millis(5));
            h.spawn(async move {
                assert!(sleep.await);
                if g2.get() == armed_gen {
                    f2.set(f2.get() + 1);
                }
            });
            // bump after arming the FIRST task only: its deadline is stale
            if armed_gen == 1 {
                generation.set(2);
            }
        }
        exec.run();
        assert_eq!(flushes.get(), 1, "stale-generation deadline must not flush");
    }
}

/// Model-checked variant of the timer fire-vs-cancel family: explores every
/// interleaving of a concurrent fire and cancel on one `SleepShared` under
/// the deterministic scheduler (`RUSTFLAGS="--cfg ciq_model"`). The
/// deterministic test above pins the *wheel-level* race at a single tick;
/// this one pins the `finish` protocol itself. Mutation M4 (see
/// `rust/tests/model_exec.rs`) removes the first-outcome-wins guard and is
/// caught here as a flipped outcome.
#[cfg(all(test, ciq_model))]
mod model_tests {
    use super::*;
    use crate::util::model;

    #[test]
    fn timer_fire_vs_cancel_outcome_is_sticky() {
        model::check(|| {
            let state =
                Arc::new(SleepShared { inner: Mutex::new(SleepInner { done: None, waker: None }) });
            let (fire, cancel) = (state.clone(), state.clone());
            // The wheel's fire path and a cancel path racing on one timer.
            let t_fire = model::spawn(move || fire.finish(true));
            let t_cancel = model::spawn(move || cancel.finish(false));
            // Observer: once an outcome is decided it must never change.
            let first = state.inner.lock().unwrap().done;
            let second = state.inner.lock().unwrap().done;
            if let (Some(a), Some(b)) = (first, second) {
                assert_eq!(a, b, "sleep outcome flipped after being decided");
            }
            t_fire.join();
            t_cancel.join();
            let done = state.inner.lock().unwrap().done;
            assert!(done.is_some(), "one of fire/cancel must decide the outcome");
            if let Some(a) = first {
                assert_eq!(done, Some(a), "decided outcome changed after the race settled");
            }
        });
    }
}

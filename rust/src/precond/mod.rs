//! Preconditioners for msMINRES-CIQ (Sec. 3.4 / Appx. D).
//!
//! The workhorse is the **partial pivoted Cholesky** preconditioner of
//! Gardner et al. [29]: a rank-`r` approximation `P = L̄ L̄ᵀ + σ² I` built
//! from `r` adaptively-pivoted columns of `K`. Because `P` is
//! low-rank-plus-scaled-identity we get *exact* `O(nr)` routines for
//! `P^{-1} x` (Woodbury) **and** `P^{±1/2} x` (spectral shift of the factor),
//! which is precisely what Appx. D requires of a CIQ preconditioner.

use crate::linalg::eigen::sym_eig;
use crate::linalg::{Matrix, SolveWorkspace};
use crate::operators::LinearOp;
use crate::{Error, Result};

/// Partial pivoted-Cholesky preconditioner `P = L Lᵀ + σ² I`.
pub struct PivotedCholesky {
    /// low-rank factor, `n × r`
    l: Matrix,
    /// diagonal term σ²
    sigma2: f64,
    /// orthonormal column basis `U` of `L` (`n × r`)
    u: Matrix,
    /// eigenvalues of `LᵀL` (spectrum of the low-rank part), length `r`
    s2: Vec<f64>,
    /// pivot order chosen during the build (empty for [`Self::from_factor`])
    pivots: Vec<usize>,
}

impl PivotedCholesky {
    /// Build a rank-≤`rank` pivoted-Cholesky approximation of `op`, with
    /// `sigma2` added to the diagonal (use the kernel's noise term, or a
    /// small fraction of the mean diagonal).
    ///
    /// Stops early if the residual diagonal drops below `tol`.
    pub fn new(op: &dyn LinearOp, rank: usize, sigma2: f64, tol: f64) -> Result<PivotedCholesky> {
        Self::new_with_hint(op, rank, sigma2, tol, None).map(|(pc, _)| pc)
    }

    /// [`Self::new`] with an optional **warm-start pivot hint**: the pivot
    /// order of a previous build on a similar operator (hyperparameter-step
    /// workloads replace operators with slightly perturbed kernels, whose
    /// greedy pivot order barely moves). While the hint holds, each step
    /// takes the hinted pivot outright — skipping the O(n) max-diagonal
    /// search pass — and falls back to the full greedy scan the moment a
    /// hinted pivot is unavailable or has a residual diagonal ≤ `tol`.
    ///
    /// Returns the factor plus the number of pivot-search passes saved.
    /// For an identical operator the hinted build reproduces the cold build
    /// bit-for-bit (the greedy argmax is exactly the hint); for a perturbed
    /// one it trades an O(n·rank) search for a possibly slightly looser
    /// (still exact-as-a-preconditioner) pivot set.
    pub fn new_with_hint(
        op: &dyn LinearOp,
        rank: usize,
        sigma2: f64,
        tol: f64,
        hint: Option<&[usize]>,
    ) -> Result<(PivotedCholesky, usize)> {
        let n = op.size();
        let rank = rank.min(n);
        if sigma2 <= 0.0 {
            return Err(Error::Invalid("pivoted Cholesky needs sigma2 > 0".into()));
        }
        let mut d = op.diagonal();
        let mut perm: Vec<usize> = (0..n).collect();
        // pos[element] = its index in perm, so a hinted pivot swaps in O(1)
        let mut pos: Vec<usize> = (0..n).collect();
        let mut l = Matrix::zeros(n, rank);
        let mut m_used = 0;
        let mut saved_passes = 0usize;
        // a hint referencing out-of-range rows (operator size changed) is
        // ignored outright
        let mut hint_live = hint.map(|h| h.iter().all(|&p| p < n)).unwrap_or(false);
        for m in 0..rank {
            let hinted = if hint_live {
                match hint.and_then(|h| h.get(m)) {
                    Some(&cand) if pos[cand] >= m && d[cand] > tol => Some(cand),
                    _ => {
                        hint_live = false;
                        None
                    }
                }
            } else {
                None
            };
            let piv = match hinted {
                Some(cand) => {
                    // accept the hinted pivot without scanning the diagonal
                    saved_passes += 1;
                    let ip = pos[cand];
                    perm.swap(m, ip);
                    pos[perm[ip]] = ip;
                    pos[perm[m]] = m;
                    cand
                }
                None => {
                    // pivot: largest remaining diagonal (full greedy pass)
                    let (rel, &piv) = perm[m..]
                        .iter()
                        .enumerate()
                        .max_by(|a, b| d[*a.1].partial_cmp(&d[*b.1]).unwrap())
                        .unwrap();
                    perm.swap(m, m + rel);
                    pos[perm[m + rel]] = m + rel;
                    pos[perm[m]] = m;
                    if d[piv] <= tol {
                        break;
                    }
                    piv
                }
            };
            let lmm = d[piv].sqrt();
            l[(piv, m)] = lmm;
            let col = op.column(piv);
            // row slice of pivot's factor entries
            let lp: Vec<f64> = (0..m).map(|p| l[(piv, p)]).collect();
            for &pj in &perm[m + 1..] {
                let mut s = col[pj];
                for p in 0..m {
                    s -= l[(pj, p)] * lp[p];
                }
                let val = s / lmm;
                l[(pj, m)] = val;
                d[pj] -= val * val;
            }
            m_used = m + 1;
        }
        // truncate unused columns
        let mut lt = Matrix::zeros(n, m_used.max(1));
        for i in 0..n {
            for j in 0..m_used {
                lt[(i, j)] = l[(i, j)];
            }
        }
        let mut pc = Self::from_factor(lt, sigma2)?;
        pc.pivots = perm[..m_used].to_vec();
        Ok((pc, saved_passes))
    }

    /// Build directly from a low-rank factor (`n × r`) and σ².
    pub fn from_factor(l: Matrix, sigma2: f64) -> Result<PivotedCholesky> {
        let r = l.cols();
        // spectral decomposition of the low-rank part: LᵀL = V S² Vᵀ,
        // U = L V S^{-1}
        let ltl = l.t_matmul(&l);
        let eig = sym_eig(&ltl)?;
        let mut u = l.matmul(&eig.vectors);
        let mut s2 = eig.values.clone();
        for j in 0..r {
            let s = s2[j].max(0.0).sqrt();
            s2[j] = s2[j].max(0.0);
            let inv = if s > 1e-12 { 1.0 / s } else { 0.0 };
            for i in 0..u.rows() {
                u[(i, j)] *= inv;
            }
        }
        Ok(PivotedCholesky { l, sigma2, u, s2, pivots: Vec::new() })
    }

    /// Pivot order chosen by the build (empty for [`Self::from_factor`]) —
    /// feed it to [`Self::new_with_hint`] to warm-start the next build on a
    /// perturbed version of the same operator.
    pub fn pivot_order(&self) -> &[usize] {
        &self.pivots
    }

    /// Dimension.
    pub fn n(&self) -> usize {
        self.l.rows()
    }

    /// Rank of the low-rank part.
    pub fn rank(&self) -> usize {
        self.l.cols()
    }

    /// The low-rank factor `L`.
    pub fn factor(&self) -> &Matrix {
        &self.l
    }

    /// σ².
    pub fn sigma2(&self) -> f64 {
        self.sigma2
    }

    /// `P x = L(Lᵀx) + σ²x` — `O(nr)`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let ltx = self.l.matvec_t(x);
        let mut y = self.l.matvec(&ltx);
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += self.sigma2 * xi;
        }
        y
    }

    /// Generic spectral map `f(P) x = σ_f x + U (f(s²+σ²) − f(σ²)) Uᵀ x`
    /// where `σ_f = f(σ²)` — exact because `P = U diag(s²+σ²) Uᵀ + σ²(I−UUᵀ)`.
    /// Thin wrapper over [`Self::spectral_apply_in`] with a transient
    /// workspace, so the owned and workspace paths are one implementation
    /// (bit-for-bit identical at every size).
    fn spectral_apply(&self, x: &[f64], f: impl Fn(f64) -> f64) -> Vec<f64> {
        let mut ws = SolveWorkspace::new();
        let mut out = vec![0.0; self.n()];
        self.spectral_apply_in(&mut ws, x, f, &mut out);
        out
    }

    /// Blocked analogue of [`Self::spectral_apply`]: `f(P) X` for all columns
    /// of `X` at once through the panel-GEMM engine (`UᵀX` → row scaling →
    /// `U·` → `+ f(σ²) X`). This is what lets the whitened operator's
    /// `matmat` keep the block solver's batch economics — the per-column
    /// route would fall back to `2·cols` skinny GEMVs. Thin wrapper over
    /// [`Self::spectral_apply_block_in`] (one engine, owned == workspace
    /// bit-for-bit).
    fn spectral_apply_block(&self, x: &Matrix, f: impl Fn(f64) -> f64) -> Matrix {
        let mut ws = SolveWorkspace::new();
        let mut out = Matrix::zeros(self.n(), x.cols());
        self.spectral_apply_block_in(&mut ws, x, f, &mut out);
        out
    }

    /// `P^{-1} x` — exact Woodbury-equivalent solve, `O(nr)`.
    pub fn solve(&self, x: &[f64]) -> Vec<f64> {
        self.spectral_apply(x, |e| 1.0 / e)
    }

    /// `P^{1/2} x` — exact, `O(nr)`.
    pub fn sqrt_mvm(&self, x: &[f64]) -> Vec<f64> {
        self.spectral_apply(x, |e| e.sqrt())
    }

    /// `P^{-1/2} x` — exact, `O(nr)`.
    pub fn invsqrt_mvm(&self, x: &[f64]) -> Vec<f64> {
        self.spectral_apply(x, |e| 1.0 / e.sqrt())
    }

    /// `P^{-1} X` for a block of columns — exact, `O(nr·cols)`.
    pub fn solve_matmat(&self, x: &Matrix) -> Matrix {
        self.spectral_apply_block(x, |e| 1.0 / e)
    }

    /// `P^{1/2} X` for a block of columns — exact, `O(nr·cols)`.
    pub fn sqrt_matmat(&self, x: &Matrix) -> Matrix {
        self.spectral_apply_block(x, |e| e.sqrt())
    }

    /// `P^{-1/2} X` for a block of columns — exact, `O(nr·cols)`.
    pub fn invsqrt_matmat(&self, x: &Matrix) -> Matrix {
        self.spectral_apply_block(x, |e| 1.0 / e.sqrt())
    }

    /// [`Self::spectral_apply`] into a pre-sized `out`, all scratch from
    /// `ws` — the single-vector leg of the zero-allocation solve path.
    fn spectral_apply_in(
        &self,
        ws: &mut SolveWorkspace,
        x: &[f64],
        f: impl Fn(f64) -> f64,
        out: &mut [f64],
    ) {
        let f0 = f(self.sigma2);
        let mut utx = ws.take_vec(self.u.cols());
        self.u.matvec_t_into(x, &mut utx);
        for (c, &s2) in utx.iter_mut().zip(&self.s2) {
            *c *= f(s2 + self.sigma2) - f0;
        }
        self.u.matvec_into(&utx, out);
        for (yi, xi) in out.iter_mut().zip(x) {
            *yi += f0 * xi;
        }
        ws.give_vec(utx);
    }

    /// [`Self::spectral_apply_block`] into a pre-sized `out`, with the
    /// `UᵀX` panel drawn from `ws` — preconditioned block solves stay
    /// allocation-free once the workspace is warm.
    fn spectral_apply_block_in(
        &self,
        ws: &mut SolveWorkspace,
        x: &Matrix,
        f: impl Fn(f64) -> f64,
        out: &mut Matrix,
    ) {
        let f0 = f(self.sigma2);
        let mut utx = ws.take_mat(self.u.cols(), x.cols());
        self.u.t_matmul_in(ws, x, &mut utx);
        for (i, &s2) in self.s2.iter().enumerate() {
            let g = f(s2 + self.sigma2) - f0;
            for j in 0..utx.cols() {
                utx[(i, j)] *= g;
            }
        }
        self.u.matmul_into(&utx, out);
        for i in 0..out.rows() {
            for j in 0..out.cols() {
                out[(i, j)] += f0 * x[(i, j)];
            }
        }
        ws.give_mat(utx);
    }

    /// `out = P^{-1} x` with scratch from `ws` — exact, `O(nr)`.
    pub fn solve_in(&self, ws: &mut SolveWorkspace, x: &[f64], out: &mut [f64]) {
        self.spectral_apply_in(ws, x, |e| 1.0 / e, out)
    }

    /// `out = P^{1/2} x` with scratch from `ws` — exact, `O(nr)`.
    pub fn sqrt_mvm_in(&self, ws: &mut SolveWorkspace, x: &[f64], out: &mut [f64]) {
        self.spectral_apply_in(ws, x, |e| e.sqrt(), out)
    }

    /// `out = P^{-1/2} x` with scratch from `ws` — exact, `O(nr)`.
    pub fn invsqrt_mvm_in(&self, ws: &mut SolveWorkspace, x: &[f64], out: &mut [f64]) {
        self.spectral_apply_in(ws, x, |e| 1.0 / e.sqrt(), out)
    }

    /// `out = P^{-1} X` with scratch from `ws` — exact, `O(nr·cols)`.
    pub fn solve_matmat_in(&self, ws: &mut SolveWorkspace, x: &Matrix, out: &mut Matrix) {
        self.spectral_apply_block_in(ws, x, |e| 1.0 / e, out)
    }

    /// `out = P^{1/2} X` with scratch from `ws` — exact, `O(nr·cols)`.
    pub fn sqrt_matmat_in(&self, ws: &mut SolveWorkspace, x: &Matrix, out: &mut Matrix) {
        self.spectral_apply_block_in(ws, x, |e| e.sqrt(), out)
    }

    /// `out = P^{-1/2} X` with scratch from `ws` — exact, `O(nr·cols)`.
    pub fn invsqrt_matmat_in(&self, ws: &mut SolveWorkspace, x: &Matrix, out: &mut Matrix) {
        self.spectral_apply_block_in(ws, x, |e| 1.0 / e.sqrt(), out)
    }
}

/// Jacobi (diagonal) preconditioner.
pub struct Jacobi {
    inv_diag: Vec<f64>,
}

impl Jacobi {
    /// Build from an operator's diagonal.
    pub fn new(op: &dyn LinearOp) -> Jacobi {
        Jacobi { inv_diag: op.diagonal().into_iter().map(|d| 1.0 / d.max(1e-300)).collect() }
    }

    /// `P^{-1} x`.
    pub fn solve(&self, x: &[f64]) -> Vec<f64> {
        self.inv_diag.iter().zip(x).map(|(d, x)| d * x).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operators::{DenseOp, KernelOp, KernelType};
    use crate::rng::Pcg64;
    use crate::util::rel_err;

    #[test]
    fn full_rank_reproduces_matrix() {
        let mut rng = Pcg64::seeded(1);
        let x = Matrix::randn(20, 2, &mut rng);
        let op = KernelOp::new(&x, KernelType::Rbf, 0.7, 1.0, 0.0);
        let pc = PivotedCholesky::new(&op, 20, 1e-3, 1e-12).unwrap();
        // P ≈ K + 1e-3 I at full rank
        let k = op.to_dense();
        let mut probe = Pcg64::seeded(2);
        let v: Vec<f64> = (0..20).map(|_| probe.normal()).collect();
        let pv = pc.matvec(&v);
        let mut kv = k.matvec(&v);
        for (kvi, vi) in kv.iter_mut().zip(&v) {
            *kvi += 1e-3 * vi;
        }
        assert!(rel_err(&pv, &kv) < 1e-6);
    }

    #[test]
    fn solve_is_exact_inverse() {
        let mut rng = Pcg64::seeded(3);
        let l = Matrix::randn(25, 5, &mut rng);
        let pc = PivotedCholesky::from_factor(l, 0.5).unwrap();
        let v: Vec<f64> = (0..25).map(|_| rng.normal()).collect();
        let pv = pc.matvec(&v);
        let back = pc.solve(&pv);
        assert!(rel_err(&back, &v) < 1e-10);
    }

    #[test]
    fn sqrt_squares_to_p() {
        let mut rng = Pcg64::seeded(4);
        let l = Matrix::randn(18, 4, &mut rng);
        let pc = PivotedCholesky::from_factor(l, 0.3).unwrap();
        let v: Vec<f64> = (0..18).map(|_| rng.normal()).collect();
        let half = pc.sqrt_mvm(&v);
        let full = pc.sqrt_mvm(&half);
        let pv = pc.matvec(&v);
        assert!(rel_err(&full, &pv) < 1e-10);
        // invsqrt(sqrt(v)) == v
        let round = pc.invsqrt_mvm(&half);
        assert!(rel_err(&round, &v) < 1e-10);
    }

    #[test]
    fn low_rank_captures_dominant_spectrum() {
        // A kernel on clustered data is near low-rank: a small-rank pivoted
        // Cholesky should make P^{-1}K well conditioned.
        let mut rng = Pcg64::seeded(5);
        let n = 60;
        let x = Matrix::randn(n, 1, &mut rng);
        let op = KernelOp::new(&x, KernelType::Rbf, 1.0, 1.0, 1e-2);
        let pc = PivotedCholesky::new(&op, 20, 1e-2, 1e-12).unwrap();
        // residual norm of K + σ²I − P should be small relative to K
        let k = {
            let mut k = op.to_dense();
            // op already includes noise 1e-2 on diag; P models it via σ²
            k
        };
        let mut probe = Pcg64::seeded(6);
        let v: Vec<f64> = (0..n).map(|_| probe.normal()).collect();
        let kv = k.matvec(&v);
        let pv = pc.matvec(&v);
        assert!(rel_err(&pv, &kv) < 0.05, "rank-20 should capture RBF on 1-D data");
    }

    #[test]
    fn pivoting_beats_no_pivoting_rank_budget() {
        // With one far-away outlier point, pivoting must select it early;
        // check the approximation error is small at tiny rank.
        let n = 30;
        let mut x = Matrix::zeros(n, 1);
        for i in 0..n - 1 {
            x[(i, 0)] = i as f64 * 0.01;
        }
        x[(n - 1, 0)] = 100.0;
        let op = KernelOp::new(&x, KernelType::Rbf, 1.0, 1.0, 0.0);
        let pc = PivotedCholesky::new(&op, 3, 1e-4, 1e-14).unwrap();
        let k = op.to_dense();
        let mut rng = Pcg64::seeded(7);
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        assert!(rel_err(&pc.matvec(&v), &k.matvec(&v)) < 0.05);
    }

    #[test]
    fn blocked_spectral_apply_matches_per_column() {
        let mut rng = Pcg64::seeded(8);
        let l = Matrix::randn(22, 5, &mut rng);
        let pc = PivotedCholesky::from_factor(l, 0.4).unwrap();
        let x = Matrix::randn(22, 6, &mut rng);
        let inv = pc.invsqrt_matmat(&x);
        let sq = pc.sqrt_matmat(&x);
        let sol = pc.solve_matmat(&x);
        for j in 0..x.cols() {
            let col = x.col(j);
            assert!(rel_err(&inv.col(j), &pc.invsqrt_mvm(&col)) < 1e-12, "invsqrt col {j}");
            assert!(rel_err(&sq.col(j), &pc.sqrt_mvm(&col)) < 1e-12, "sqrt col {j}");
            assert!(rel_err(&sol.col(j), &pc.solve(&col)) < 1e-12, "solve col {j}");
        }
    }

    #[test]
    fn hint_on_identical_operator_reproduces_factor_and_skips_every_search() {
        let mut rng = Pcg64::seeded(9);
        let x = Matrix::randn(40, 2, &mut rng);
        let op = KernelOp::new(&x, KernelType::Rbf, 0.8, 1.0, 1e-2);
        let (cold, saved_cold) = PivotedCholesky::new_with_hint(&op, 12, 1e-2, 1e-12, None).unwrap();
        assert_eq!(saved_cold, 0);
        assert_eq!(cold.pivot_order().len(), cold.rank());
        let (warm, saved) =
            PivotedCholesky::new_with_hint(&op, 12, 1e-2, 1e-12, Some(cold.pivot_order())).unwrap();
        assert_eq!(saved, cold.rank(), "every pivot-search pass must be skipped");
        assert_eq!(warm.pivot_order(), cold.pivot_order());
        assert_eq!(cold.factor().max_abs_diff(warm.factor()), 0.0, "hinted factor must be bit-identical");
    }

    #[test]
    fn hint_on_perturbed_operator_still_builds_valid_preconditioner() {
        let mut rng = Pcg64::seeded(10);
        let x = Matrix::randn(50, 1, &mut rng);
        let op_a = KernelOp::new(&x, KernelType::Rbf, 1.0, 1.0, 1e-2);
        let (cold, _) = PivotedCholesky::new_with_hint(&op_a, 16, 1e-2, 1e-12, None).unwrap();
        // a hyperparameter step: slightly different lengthscale
        let op_b = KernelOp::new(&x, KernelType::Rbf, 1.05, 1.0, 1e-2);
        let (warm, saved) =
            PivotedCholesky::new_with_hint(&op_b, 16, 1e-2, 1e-12, Some(cold.pivot_order())).unwrap();
        assert!(saved > 0, "perturbed rebuild must reuse at least some hinted pivots");
        // the warm factor still approximates the *new* operator
        let k = op_b.to_dense();
        let v: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
        assert!(rel_err(&warm.matvec(&v), &k.matvec(&v)) < 0.05);
        // a stale hint from a different-size operator is ignored, not trusted
        let x_small = Matrix::randn(20, 1, &mut rng);
        let op_c = KernelOp::new(&x_small, KernelType::Rbf, 1.0, 1.0, 1e-2);
        let (_, saved_c) =
            PivotedCholesky::new_with_hint(&op_c, 8, 1e-2, 1e-12, Some(cold.pivot_order())).unwrap();
        assert_eq!(saved_c, 0, "out-of-range hint must be ignored");
    }

    #[test]
    fn workspace_spectral_applies_match_and_stay_warm() {
        let mut rng = Pcg64::seeded(11);
        let l = Matrix::randn(24, 5, &mut rng);
        let pc = PivotedCholesky::from_factor(l, 0.4).unwrap();
        let x = Matrix::randn(24, 6, &mut rng);
        let v: Vec<f64> = (0..24).map(|_| rng.normal()).collect();
        let mut ws = crate::linalg::SolveWorkspace::new();
        for _ in 0..2 {
            let mut out = ws.take_mat(24, 6);
            pc.invsqrt_matmat_in(&mut ws, &x, &mut out);
            assert_eq!(out.max_abs_diff(&pc.invsqrt_matmat(&x)), 0.0);
            pc.sqrt_matmat_in(&mut ws, &x, &mut out);
            assert_eq!(out.max_abs_diff(&pc.sqrt_matmat(&x)), 0.0);
            pc.solve_matmat_in(&mut ws, &x, &mut out);
            assert_eq!(out.max_abs_diff(&pc.solve_matmat(&x)), 0.0);
            ws.give_mat(out);
            let mut outv = ws.take_vec(24);
            pc.invsqrt_mvm_in(&mut ws, &v, &mut outv);
            assert_eq!(outv, pc.invsqrt_mvm(&v));
            pc.sqrt_mvm_in(&mut ws, &v, &mut outv);
            assert_eq!(outv, pc.sqrt_mvm(&v));
            pc.solve_in(&mut ws, &v, &mut outv);
            assert_eq!(outv, pc.solve(&v));
            ws.give_vec(outv);
        }
        let grows = ws.grows();
        let mut out = ws.take_mat(24, 6);
        pc.invsqrt_matmat_in(&mut ws, &x, &mut out);
        ws.give_mat(out);
        assert_eq!(ws.grows(), grows, "warmed spectral apply re-allocated");
    }

    #[test]
    fn jacobi_inverts_diagonal() {
        let d = DenseOp::new(Matrix::from_vec(3, 3, vec![2.0, 0.0, 0.0, 0.0, 4.0, 0.0, 0.0, 0.0, 8.0]));
        let j = Jacobi::new(&d);
        let y = j.solve(&[2.0, 4.0, 8.0]);
        assert_eq!(y, vec![1.0, 1.0, 1.0]);
    }
}

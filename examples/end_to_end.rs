//! END-TO-END DRIVER: proves all three layers compose on a real workload.
//!
//! Layer 1 (Pallas kernel-MVM) and Layer 2 (JAX msMINRES-CIQ) were AOT-lowered
//! to HLO text by `make artifacts`; this binary
//!
//! 1. loads + compiles the artifacts on the PJRT CPU client (Layer 3 runtime),
//! 2. cross-checks the XLA CIQ pipeline against the native Rust solver,
//! 3. registers the *XLA-backed* kernel operator with the batching
//!    coordinator and serves concurrent sampling/whitening traffic through
//!    it — Python is nowhere on this request path —
//! 4. reports correctness, throughput and latency percentiles.
//!
//! Run: `make artifacts && cargo run --release --example end_to_end`

use ciq::ciq::{Ciq, CiqOptions};
use ciq::coordinator::{ReqKind, SamplingService, ServiceConfig, SharedOp};
use ciq::linalg::Matrix;
use ciq::operators::{KernelOp, KernelType};
use ciq::rng::Pcg64;
use ciq::runtime::{artifacts_dir, discover_artifacts, Runtime, XlaCiq, XlaKernelMvm};
use ciq::util::rel_err;
use std::collections::HashMap;
use std::sync::Arc;

fn main() -> ciq::Result<()> {
    let dir = artifacts_dir();
    let metas = discover_artifacts(&dir);
    if metas.is_empty() {
        eprintln!("no artifacts in {} — run `make artifacts` first", dir.display());
        std::process::exit(1);
    }

    // the Runtime must outlive the service's operators; leak it (one-shot binary)
    let rt: &'static Runtime = Box::leak(Box::new(Runtime::cpu()?));
    println!("== end-to-end: PJRT platform = {} ==", rt.platform());

    // ---- 1+2: XLA CIQ pipeline vs native Rust ----
    let ciq_meta = metas.iter().find(|m| m.kind == "ciq_sqrt").expect("ciq artifact");
    let exe = rt.load(ciq_meta)?;
    let xla_ciq = XlaCiq::new(rt, exe)?;
    let (n, d) = (ciq_meta.n, ciq_meta.d);
    let mut rng = Pcg64::seeded(7);
    let x = Matrix::randn(n, d, &mut rng);
    let (ell, s2, noise) = (0.9, 1.0, 0.3);
    let native_op = KernelOp::new(&x, KernelType::Rbf, ell, s2, noise);
    let solver = Ciq::new(CiqOptions { q_points: ciq_meta.q, tol: 1e-6, ..Default::default() });
    let (rule, bounds) = solver.rule(&native_op, None)?;
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let out = xla_ciq.run(&x, ell, s2, noise, &b, &rule.shifts, &rule.weights)?;
    let native = solver.sqrt_mvm(&native_op, &b)?;
    println!(
        "XLA ciq_sqrt (N={n}, Q={}, J={}): residual {:.1e}, vs native rel err {:.1e}, kappa≈{:.1}",
        ciq_meta.q,
        ciq_meta.j,
        out.residual,
        rel_err(&out.sqrt, &native.solution),
        bounds.kappa()
    );

    // ---- 3: serve traffic through the XLA-backed kernel operator ----
    let mvm_meta = metas
        .iter()
        .find(|m| m.kind == "kernel_mvm" && m.kernel == "rbf")
        .expect("kernel_mvm artifact");
    let exe = rt.load(mvm_meta)?;
    let xla_op: SharedOp = Arc::new(XlaKernelMvm::new(rt, exe, &x, ell, s2, noise)?);
    let mut ops = HashMap::new();
    ops.insert("xla-rbf".to_string(), xla_op);
    let svc = Arc::new(SamplingService::start(
        ServiceConfig {
            max_batch: mvm_meta.r,
            workers: 2,
            ciq: CiqOptions { tol: 1e-4, max_iters: 200, ..Default::default() },
            ..Default::default()
        },
        ops,
    ));

    let clients = 4;
    let per_client = 6;
    let t0 = std::time::Instant::now();
    let errs = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..clients {
            let svc = svc.clone();
            handles.push(scope.spawn(move || {
                let mut rng = Pcg64::seeded(1000 + c as u64);
                let mut bad = 0.0f64;
                for r in 0..per_client {
                    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                    let kind = if r % 2 == 0 { ReqKind::Whiten } else { ReqKind::Sample };
                    let out = svc.submit("xla-rbf", kind, b).wait().expect("request");
                    bad += out.iter().filter(|v| !v.is_finite()).count() as f64;
                }
                bad
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).fold(0.0f64, f64::max)
    });
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(errs, 0.0, "non-finite outputs from service");
    let total = clients * per_client;
    println!(
        "served {total} requests through the Pallas/PJRT MVM in {dt:.2}s ({:.1} req/s)",
        total as f64 / dt
    );
    println!("metrics: {}", svc.metrics().summary());

    // one precise roundtrip through the service for correctness
    let b2: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let w = svc.submit("xla-rbf", ReqKind::Whiten, b2.clone()).wait()?;
    let s = svc.submit("xla-rbf", ReqKind::Sample, w).wait()?;
    let round = rel_err(&s, &b2);
    println!("service whiten→sample roundtrip rel err: {round:.2e}");
    assert!(round < 1e-2, "roundtrip through XLA-backed service too lossy");
    println!("END-TO-END OK: Pallas (L1) → JAX (L2) → HLO → PJRT → coordinator (L3)");
    Ok(())
}

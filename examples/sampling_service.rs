//! The L3 coordinator in action: a batching sampling service multiplexing
//! concurrent `K^{±1/2} b` requests from many client threads, with latency
//! and batching metrics, policy-driven preconditioning, background context
//! warming, and adaptive per-shard batch ceilings.
//!
//! Run: `cargo run --release --example sampling_service -- [--n 2000]
//!   [--clients 8] [--policy plain|cached|precond] [--rank 48]
//!   [--adaptive-ms 50] [--adaptive-wait-us 200]`

use ciq::ciq::{PrecondConfig, SolverPolicy};
use ciq::coordinator::{
    AdaptiveBatchConfig, AdaptiveWaitConfig, ReqKind, SamplingService, ServiceConfig, SharedOp,
};
use ciq::linalg::Matrix;
use ciq::operators::{KernelOp, KernelType};
use ciq::rng::Pcg64;
use ciq::util::cli::Args;
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args = Args::parse();
    let n = args.get_or("n", 2000usize);
    let clients = args.get_or("clients", 8usize);
    let per_client = args.get_or("requests", 8usize);
    let policy = match args.get("policy").unwrap_or("cached") {
        "plain" => SolverPolicy::Plain,
        "precond" => SolverPolicy::Preconditioned(PrecondConfig {
            rank: args.get_or("rank", 48usize),
            sigma2: Some(1e-2),
            ..Default::default()
        }),
        _ => SolverPolicy::CachedBounds,
    };
    let adaptive_ms = args.get_or("adaptive-ms", 0u64);
    let adaptive_wait_us = args.get_or("adaptive-wait-us", 0u64);

    let mut rng = Pcg64::seeded(0);
    let x = Matrix::randn(n, 2, &mut rng);
    let rbf: SharedOp = Arc::new(KernelOp::new(&x, KernelType::Rbf, 1.0, 1.0, 1e-2));
    let mat: SharedOp = Arc::new(KernelOp::new(&x, KernelType::Matern52, 1.0, 1.0, 1e-2));
    let mut ops = HashMap::new();
    ops.insert("rbf".to_string(), rbf);
    ops.insert("matern".to_string(), mat);

    let svc = Arc::new(SamplingService::start(
        ServiceConfig {
            max_batch: 16,
            workers: 2,
            policy,
            adaptive: (adaptive_ms > 0).then(|| AdaptiveBatchConfig {
                target_flush_latency: Duration::from_millis(adaptive_ms),
                min_batch: 1,
            }),
            adaptive_wait: (adaptive_wait_us > 0).then(|| AdaptiveWaitConfig {
                min_wait: Duration::from_micros(adaptive_wait_us),
            }),
            ..Default::default()
        },
        ops,
    ));

    println!(
        "== sampling service (async dispatcher): {clients} clients × {per_client} \
         requests, N = {n} =="
    );
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let svc = svc.clone();
            scope.spawn(move || {
                let mut rng = Pcg64::seeded(100 + c as u64);
                for r in 0..per_client {
                    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                    let op = if c % 2 == 0 { "rbf" } else { "matern" };
                    let kind = if r % 2 == 0 { ReqKind::Sample } else { ReqKind::Whiten };
                    let out = svc.submit(op, kind, b).wait().expect("request failed");
                    assert_eq!(out.len(), n);
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let total = clients * per_client;
    println!("served {total} requests in {dt:.2}s ({:.1} req/s)", total as f64 / dt);
    println!("metrics: {}", svc.metrics().summary());
    println!(
        "batching: mean batch {:.1}, max {}",
        svc.metrics().mean_batch_size(),
        svc.metrics().max_batch_size()
    );
    println!(
        "spectral cache: hits={} misses={} saved_mvms={} (warmed={} warm_failures={})",
        svc.metrics().cache_hits.load(Ordering::Relaxed),
        svc.metrics().cache_misses.load(Ordering::Relaxed),
        svc.metrics().saved_mvms.load(Ordering::Relaxed),
        svc.metrics().warmed_operators.load(Ordering::Relaxed),
        svc.metrics().warm_failures.load(Ordering::Relaxed),
    );
    println!(
        "dispatcher: wakeups={} timer_fires={} (event/deadline-driven only — zero at idle)",
        svc.metrics().dispatcher_wakeups.load(Ordering::Relaxed),
        svc.metrics().timer_fires.load(Ordering::Relaxed),
    );
    println!(
        "workspaces: checkouts={} grows={} peak_bytes={} (grows stand still once warm)",
        svc.metrics().workspace_checkouts.load(Ordering::Relaxed),
        svc.metrics().workspace_grows.load(Ordering::Relaxed),
        svc.metrics().workspace_bytes_high_water.load(Ordering::Relaxed),
    );
    let ceilings = svc.metrics().batch_ceilings();
    if !ceilings.is_empty() {
        println!("adaptive batch ceilings:");
        for (shard, c) in ceilings {
            println!("  {shard:<16} {c}");
        }
    }
    let waits = svc.metrics().shard_waits();
    if !waits.is_empty() {
        println!("adaptive flush waits (us):");
        for (shard, us) in waits {
            println!("  {shard:<16} {us}");
        }
    }
    println!(
        "compaction: {} matmat columns paid, {} saved vs uncompacted",
        svc.metrics().column_work.load(Ordering::Relaxed),
        svc.metrics().saved_column_work(),
    );
    println!("shard queue depths (current/max):");
    for (shard, cur, max) in svc.metrics().shard_depths() {
        println!("  {shard:<16} {cur}/{max}");
    }
    println!("msMINRES iteration histogram (Fig. S7 from live traffic):");
    for (bucket, count) in svc.metrics().iteration_histogram(10) {
        println!("  {:>4}-{:<4} {}", bucket, bucket + 9, "#".repeat(count.min(60)));
    }
}

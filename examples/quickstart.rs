//! Quickstart: sample from `N(0, K)` and whiten a vector with msMINRES-CIQ,
//! comparing against dense Cholesky on a size where both are feasible.
//!
//! Run: `cargo run --release --example quickstart`

use ciq::ciq::{Ciq, CiqOptions};
use ciq::linalg::{Cholesky, Matrix};
use ciq::operators::{KernelOp, KernelType, LinearOp};
use ciq::rng::Pcg64;
use ciq::util::{rel_err, timed};

fn main() -> ciq::Result<()> {
    let n = 1500;
    let mut rng = Pcg64::seeded(42);
    let x = Matrix::randn(n, 3, &mut rng);
    let op = KernelOp::new(&x, KernelType::Matern52, 0.8, 1.0, 1e-2);

    println!("== msMINRES-CIQ quickstart (N = {n}) ==");

    // K^{1/2} eps — a sample with covariance K
    let eps: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let solver = Ciq::new(CiqOptions { q_points: 8, tol: 1e-6, ..Default::default() });
    let (res, t_ciq) = timed(|| solver.sqrt_mvm(&op, &eps));
    let res = res?;
    println!(
        "CIQ   K^(1/2)b : {} MVMs, residual {:.1e}, {:.3}s",
        res.iterations, res.residual, t_ciq
    );

    // Exact identity: ‖K^{1/2}b‖² = bᵀKb (rotation-invariant check).
    let quad = ciq::util::dot(&eps, &op.matvec(&eps)).sqrt();
    let norm_ciq = ciq::util::norm2(&res.solution);
    println!(
        "identity check ‖K^(1/2)b‖ = sqrt(bᵀKb): CIQ {:.6} vs exact {:.6} (rel {:.1e})",
        norm_ciq,
        quad,
        (norm_ciq - quad).abs() / quad
    );

    // Cholesky baseline (O(N^3)): `L b` is the same sample up to an
    // orthonormal rotation of b (equal in distribution, not per-vector).
    let (chol, t_chol) = timed(|| Cholesky::with_jitter(&op.to_dense(), 0.0));
    let chol = chol?;
    let _l_eps = chol.sample_mvm(&eps);
    println!("Chol  L b      : factorization {:.3}s", t_chol);

    // whiten-then-sample roundtrip: K^(1/2) K^(-1/2) b = b
    let w = solver.invsqrt_mvm(&op, &eps)?;
    let back = solver.sqrt_mvm(&op, &w.solution)?;
    println!("roundtrip rel err: {:.2e}", rel_err(&back.solution, &eps));
    Ok(())
}

//! Thompson-sampling Bayesian optimization on Hartmann-6 (Fig. 4 left):
//! compares candidate-set sizes and samplers.
//!
//! Run: `cargo run --release --example bo_thompson -- [--evals 40] [--reps 3]`

use ciq::bo::{run_bo, testfns::Hartmann6, BoConfig, Problem, Sampler};
use ciq::util::cli::Args;

fn main() -> ciq::Result<()> {
    let args = Args::parse();
    let evals = args.get_or("evals", 40usize);
    let reps = args.get_or("reps", 3u64);
    let problem = Hartmann6;
    let opt = problem.optimum().unwrap();

    println!("== Thompson-sampling BO on {} (optimum {:.4}) ==", problem.name(), opt);
    println!("{:<18} {:>8} {:>12}", "config", "T", "mean regret");
    for (label, sampler, t) in [
        ("Cholesky-500", Sampler::Cholesky, 500),
        ("CIQ-500", Sampler::Ciq, 500),
        ("CIQ-2000", Sampler::Ciq, 2000),
        ("RFF-2000", Sampler::Rff, 2000),
    ] {
        let mut regrets = Vec::new();
        for rep in 0..reps {
            let cfg = BoConfig {
                candidates: t,
                evaluations: evals,
                sampler,
                fit_steps: 10,
                ..Default::default()
            };
            let trace = run_bo(&problem, &cfg, 100 + rep)?;
            regrets.push(trace.best() - opt);
        }
        println!("{:<18} {:>8} {:>12.4}", label, t, ciq::util::mean(&regrets));
    }
    println!("(larger candidate sets improve regret; CIQ scales where Cholesky cannot)");
    Ok(())
}

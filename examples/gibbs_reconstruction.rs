//! Image super-resolution by Gibbs sampling (Fig. 5): reconstructs a
//! high-resolution image from R blurred, decimated, noisy observations.
//! Writes truth / observation / reconstruction as PGM files.
//!
//! Run: `cargo run --release --example gibbs_reconstruction -- [--n 48] [--samples 60]`

use ciq::gibbs::{reconstruct, synthesize_observations, test_image, write_pgm, GibbsConfig};
use ciq::operators::image::PrecisionOp;
use ciq::rng::Pcg64;
use ciq::util::cli::Args;
use std::path::Path;

fn main() -> ciq::Result<()> {
    let args = Args::parse();
    let cfg = GibbsConfig {
        n: args.get_or("n", 48usize),
        samples: args.get_or("samples", 60usize),
        burn_in: args.get_or("burn-in", 20usize),
        ..Default::default()
    };
    println!(
        "== Gibbs super-resolution: {}x{} latent ({} dims), {} obs at {}x{} ==",
        cfg.n,
        cfg.n,
        cfg.n * cfg.n,
        cfg.r,
        cfg.n / cfg.factor,
        cfg.n / cfg.factor
    );
    let res = reconstruct(&cfg, args.get_or("seed", 0u64))?;
    println!(
        "rmse={:.4}  throughput={:.2} samples/s  mean CIQ iters/sample={:.0}",
        res.rmse,
        1.0 / res.seconds_per_sample.max(1e-9),
        res.mean_ciq_iters
    );
    let tail = cfg.samples - cfg.burn_in;
    println!(
        "posterior gamma_obs ≈ {:.0} (truth {:.0}), gamma_prior ≈ {:.1}",
        ciq::util::mean(&res.gamma_obs_trace[cfg.samples - tail..]),
        cfg.gamma_obs_true,
        ciq::util::mean(&res.gamma_prior_trace[cfg.samples - tail..]),
    );

    // write PGMs for eyeballing
    let io_err = |e: std::io::Error| ciq::Error::Runtime(format!("pgm: {e}"));
    let truth = test_image(cfg.n);
    write_pgm(Path::new("gibbs_truth.pgm"), &truth, cfg.n).map_err(io_err)?;
    write_pgm(Path::new("gibbs_recon.pgm"), &res.reconstruction, cfg.n).map_err(io_err)?;
    let prec = PrecisionOp::new(cfg.n, cfg.factor, cfg.r, 1.0, 1.0);
    let mut rng = Pcg64::seeded(args.get_or("seed", 0u64));
    let obs = synthesize_observations(&truth, &prec, 1, cfg.gamma_obs_true, &mut rng);
    let m = cfg.n / cfg.factor;
    write_pgm(Path::new("gibbs_observation.pgm"), &obs[0], m).map_err(io_err)?;
    println!("wrote gibbs_truth.pgm, gibbs_observation.pgm, gibbs_recon.pgm");
    Ok(())
}

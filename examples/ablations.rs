//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. **Q (quadrature points)** — accuracy/cost trade-off of the Hale rule;
//! 2. **stopping criterion** — max-over-shifts residual vs the CIQ-aware
//!    weighted residual (`CiqOptions::weighted_stop`);
//! 3. **preconditioner rank** — iterations saved vs setup cost;
//! 4. **eigenvalue-estimation budget** — Lanczos iterations for (λmin, λmax).
//!
//! Run: `cargo run --release --example ablations -- [--n 800]`

use ciq::ciq::{Ciq, CiqOptions};
use ciq::linalg::eigen::spd_inv_sqrt;
use ciq::linalg::Matrix;
use ciq::operators::{KernelOp, KernelType, LinearOp};
use ciq::precond::PivotedCholesky;
use ciq::rng::Pcg64;
use ciq::util::cli::Args;
use ciq::util::{rel_err, timed};

fn main() -> ciq::Result<()> {
    let args = Args::parse();
    let n = args.get_or("n", 800usize);
    let mut rng = Pcg64::seeded(0);
    let x = Matrix::randn(n, 2, &mut rng);
    let op = KernelOp::new(&x, KernelType::Rbf, 0.8, 1.0, 1e-3);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let exact = spd_inv_sqrt(&op.to_dense())?.matvec(&b);

    println!("== ablation 1: quadrature points Q (tol 1e-6) ==");
    println!("{:<4} {:>10} {:>8} {:>8}", "Q", "rel_err", "iters", "secs");
    for q in [3usize, 6, 8, 12, 20] {
        let solver = Ciq::new(CiqOptions { q_points: q, tol: 1e-6, max_iters: 600, ..Default::default() });
        let (res, secs) = timed(|| solver.invsqrt_mvm(&op, &b));
        let res = res?;
        println!("{:<4} {:>10.2e} {:>8} {:>8.3}", q, rel_err(&res.solution, &exact), res.iterations, secs);
    }

    println!("\n== ablation 2: stopping criterion (max-shift vs CIQ-weighted) ==");
    println!("{:<10} {:>10} {:>8}", "criterion", "rel_err", "iters");
    for weighted in [false, true] {
        let solver = Ciq::new(CiqOptions {
            q_points: 8,
            tol: 1e-5,
            max_iters: 600,
            weighted_stop: weighted,
            ..Default::default()
        });
        let res = solver.invsqrt_mvm(&op, &b)?;
        println!(
            "{:<10} {:>10.2e} {:>8}",
            if weighted { "weighted" } else { "max" },
            rel_err(&res.solution, &exact),
            res.iterations
        );
    }
    println!("(weighted stopping exits earlier at equal delivered accuracy: the");
    println!(" large-shift systems converge first and carry small weights)");

    println!("\n== ablation 3: pivoted-Cholesky preconditioner rank ==");
    println!("{:<6} {:>8} {:>10}", "rank", "iters", "setup_s");
    let solver = Ciq::new(CiqOptions { q_points: 8, tol: 1e-5, max_iters: 1500, ..Default::default() });
    let plain = solver.invsqrt_mvm(&op, &b)?;
    println!("{:<6} {:>8} {:>10}", 0, plain.iterations, "-");
    for rank in [25usize, 75, 150] {
        let (pc, setup) = timed(|| PivotedCholesky::new(&op, rank, 1e-3, 1e-14));
        let pc = pc?;
        let res = solver.invsqrt_mvm_preconditioned(&op, &pc, &b)?;
        println!("{:<6} {:>8} {:>10.3}", rank, res.iterations, setup);
    }

    println!("\n== ablation 4: Lanczos budget for (λmin, λmax) estimation ==");
    println!("{:<6} {:>12} {:>10}", "iters", "kappa_est", "rel_err");
    for li in [5usize, 10, 15, 30] {
        let solver = Ciq::new(CiqOptions { q_points: 8, tol: 1e-6, lanczos_iters: li, ..Default::default() });
        let res = solver.invsqrt_mvm(&op, &b)?;
        println!("{:<6} {:>12.2e} {:>10.2e}", li, res.bounds.kappa(), rel_err(&res.solution, &exact));
    }
    println!("(the quadrature is insensitive to over-estimating kappa — Lemma 1)");
    Ok(())
}

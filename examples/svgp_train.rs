//! Train whitened SVGP models (Fig. 3): CIQ vs Cholesky backends across
//! inducing-point counts, reporting NLL / error / per-step time.
//!
//! Run: `cargo run --release --example svgp_train -- [--n 3000] [--steps 40] [--ms 64,128]`

use ciq::ciq::CiqOptions;
use ciq::data::gaussian_regression;
use ciq::operators::KernelType;
use ciq::rng::Pcg64;
use ciq::svgp::{evaluate, train, Backend, Gaussian, Svgp, SvgpHyper};
use ciq::util::cli::Args;

fn main() -> ciq::Result<()> {
    let args = Args::parse();
    let n = args.get_or("n", 3000usize);
    let steps = args.get_or("steps", 40usize);
    let ms = args.get_list("ms", &[64usize, 128]);

    let ds = gaussian_regression(n, 2, 0.1, 7);
    let mut rng = Pcg64::seeded(1);
    let (train_set, test_set) = ds.split(0.8, &mut rng);
    println!("== SVGP on {} (train {}, test {}) ==", ds.name, train_set.len(), test_set.len());
    println!("{:<10} {:>6} {:>10} {:>10} {:>12}", "backend", "M", "NLL", "RMSE", "ms/step");

    for &m in &ms {
        for (label, backend) in [
            ("cholesky", Backend::Cholesky),
            ("ciq", Backend::Ciq(CiqOptions { tol: 1e-3, max_iters: 200, ..Default::default() })),
        ] {
            let mut rng_run = Pcg64::seeded(2);
            let z = train_set.kmeans_centers(m, 6, &mut rng_run);
            let mut model = Svgp::new(
                z,
                KernelType::Rbf,
                SvgpHyper::default(),
                Box::new(Gaussian { noise: 0.05 }),
                backend,
            );
            let stats = train(&mut model, &train_set, steps, 128, 0.5, 0.02, &mut rng_run)?;
            let metrics = evaluate(&mut model, &test_set)?;
            println!(
                "{:<10} {:>6} {:>10.4} {:>10.4} {:>12.1}",
                label,
                m,
                metrics.nll,
                metrics.error,
                1000.0 * stats.seconds / steps as f64
            );
        }
    }
    println!("(NLL improves with M; CIQ matches Cholesky accuracy while scaling to larger M)");
    Ok(())
}

//! Observability dump: drive a small workload through the sampling service
//! with the flight recorder and the residual-trajectory sampler turned on,
//! then export everything the service can tell you about itself —
//!
//! - the typed metrics snapshot as Prometheus text exposition and as JSON,
//! - sampled per-solve residual trajectories (msMINRES convergence, live),
//! - the flight-recorder timeline as Chrome trace-event JSON, loadable in
//!   Perfetto (https://ui.perfetto.dev) or `chrome://tracing`.
//!
//! The workload runs under the mixed-precision policy by default
//! (`--precision f64` restores the pure-f64 tier), so the dump also shows
//! the precision telemetry: `solves_mixed` / `refine_sweeps` /
//! `precision_fallbacks` in both expositions, and the recorder's
//! `RefineSweep` events on the timeline.
//!
//! Run: `cargo run --release --example obs_dump -- [--n 600] [--clients 4]
//!   [--requests 6] [--sample-every 2] [--precision mixed|f64]
//!   [--trace-out obs_trace.json]`

use ciq::ciq::CiqOptions;
use ciq::coordinator::{ReqKind, SamplingService, ServiceConfig, SharedOp};
use ciq::linalg::{Matrix, Precision, RefineConfig};
use ciq::obs::solvetrace;
use ciq::obs::trace::{self, EventKind};
use ciq::operators::{KernelOp, KernelType};
use ciq::rng::Pcg64;
use ciq::util::cli::Args;
use std::collections::HashMap;
use std::sync::Arc;

fn main() {
    let args = Args::parse();
    let n = args.get_or("n", 600usize);
    let clients = args.get_or("clients", 4usize);
    let per_client = args.get_or("requests", 6usize);
    let sample_every = args.get_or("sample-every", 2u64);
    let trace_out = args.get("trace-out").unwrap_or("obs_trace.json").to_string();
    let precision = match args.get("precision").unwrap_or("mixed") {
        "f64" => Precision::F64,
        _ => Precision::Mixed(RefineConfig::default()),
    };

    let mut rng = Pcg64::seeded(0);
    let x = Matrix::randn(n, 2, &mut rng);
    let rbf: SharedOp = Arc::new(KernelOp::new(&x, KernelType::Rbf, 1.0, 1.0, 1e-2));
    let mut ops = HashMap::new();
    ops.insert("rbf".to_string(), rbf);

    // Turn the full observability surface on *before* traffic: the flight
    // recorder (per-thread event rings) and the 1-in-N residual sampler.
    trace::set_enabled(true);
    solvetrace::configure(sample_every);

    let svc = Arc::new(SamplingService::start(
        ServiceConfig {
            max_batch: 8,
            workers: 2,
            ciq: CiqOptions { precision, ..Default::default() },
            ..Default::default()
        },
        ops,
    ));

    println!("== observability dump: {clients} clients × {per_client} requests, N = {n} ==");
    std::thread::scope(|scope| {
        for c in 0..clients {
            let svc = svc.clone();
            scope.spawn(move || {
                let mut rng = Pcg64::seeded(100 + c as u64);
                for r in 0..per_client {
                    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                    let kind = if r % 2 == 0 { ReqKind::Sample } else { ReqKind::Whiten };
                    let out = svc.submit("rbf", kind, b).wait().expect("request failed");
                    assert_eq!(out.len(), n);
                }
            });
        }
    });

    trace::set_enabled(false);
    solvetrace::configure(0);

    // 1. Typed metrics snapshot — Prometheus text exposition, then JSON.
    let snap = svc.metrics().snapshot();
    println!("\n--- Prometheus exposition ---");
    print!("{}", snap.to_prometheus());
    println!("\n--- metrics JSON ---");
    println!("{}", snap.to_json());

    // 2. Sampled residual trajectories: msMINRES convergence from live traffic.
    let trajs = solvetrace::drain();
    println!("\n--- sampled residual trajectories ({} solves) ---", trajs.len());
    for (i, t) in trajs.iter().enumerate() {
        let first = t.residuals.first().copied().unwrap_or(0.0);
        let last = t.residuals.last().copied().unwrap_or(0.0);
        println!(
            "  solve {i:>2}: cols={} iters={} tol={:.1e}  residual {first:.3e} -> {last:.3e}",
            t.cols, t.iters, t.tol
        );
    }

    // 3. Flight-recorder timeline: summarize, then export Chrome trace JSON.
    let trace_snap = trace::snapshot();
    let enqueues = trace_snap.of_kind(EventKind::Enqueue).count();
    let responds = trace_snap.of_kind(EventKind::Respond).count();
    let solves = trace_snap.of_kind(EventKind::SolveEnd).count();
    let sweeps = trace_snap.of_kind(EventKind::RefineSweep).count();
    println!(
        "\nflight recorder: {} events ({enqueues} enqueues, {responds} responds, \
         {solves} solve spans, {sweeps} refine sweeps)",
        trace_snap.events.len()
    );
    println!(
        "precision policy: {} mixed solves, {} f64 solves, {} refinement sweeps, \
         {} fallbacks",
        snap.solves_mixed, snap.solves_f64, snap.refine_sweeps, snap.precision_fallbacks
    );
    let chrome = trace_snap.to_chrome_json();
    std::fs::write(&trace_out, &chrome).expect("write trace file");
    println!(
        "wrote {} ({} bytes) — load it at https://ui.perfetto.dev or chrome://tracing",
        trace_out,
        chrome.len()
    );
}

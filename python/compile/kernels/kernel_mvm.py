"""Layer 1: tiled kernel-matrix MVM as a Pallas kernel.

The whole msMINRES-CIQ stack reduces to repeated products ``K @ B`` with a
kernel matrix ``K_ij = s^2 rho(||x_i - x_j|| / ell)`` that is never
materialized. This kernel computes the product tile by tile:

* grid = (row_tiles, col_tiles); each step loads a ``(tm, d)`` block of rows,
  a ``(tn, d)`` block of columns and a ``(tn, r)`` block of ``B`` into VMEM,
* the pairwise squared distances are formed through an MXU-friendly
  contraction ``|x|^2 + |y|^2 - 2 x @ y^T`` (a ``(tm, d) x (d, tn)`` matmul),
* the kernel tile is evaluated in registers and immediately contracted
  against the ``B`` block (a second matmul), accumulating into the
  ``(tm, r)`` output block that lives in VMEM across the column-tile loop.

This is the TPU re-thinking of the paper's CUDA map-reduce MVMs: the
BlockSpec index maps below express the HBM<->VMEM schedule that the paper's
GPU implementation expressed with threadblocks (DESIGN.md
section "Hardware adaptation").

Pallas runs with ``interpret=True`` (the image's PJRT plugin is CPU-only;
real-TPU lowering would emit a Mosaic custom call). Numerics are identical.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# kernel families (static argument)
RBF = 0
MATERN12 = 1
MATERN32 = 2
MATERN52 = 3

_SQRT3 = 3.0 ** 0.5
_SQRT5 = 5.0 ** 0.5


def _rho(kind: int, d2):
    """Correlation as a function of squared scaled distance (traced)."""
    if kind == RBF:
        return jnp.exp(-0.5 * d2)
    r = jnp.sqrt(jnp.maximum(d2, 1e-30))
    if kind == MATERN12:
        return jnp.exp(-r)
    if kind == MATERN32:
        a = _SQRT3 * r
        return (1.0 + a) * jnp.exp(-a)
    if kind == MATERN52:
        a = _SQRT5 * r
        return (1.0 + a + a * a / 3.0) * jnp.exp(-a)
    raise ValueError(f"unknown kernel kind {kind}")


def _mvm_kernel(kind, x_ref, sq_ref, xt_ref, sqt_ref, b_ref, s2_ref, o_ref):
    """One (row_tile, col_tile) grid step."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xi = x_ref[...]          # (tm, d)
    xj = xt_ref[...]         # (tn, d)
    # MXU contraction for pairwise distances
    inner = jnp.dot(xi, xj.T)                     # (tm, tn)
    d2 = sq_ref[...][:, None] + sqt_ref[...][None, :] - 2.0 * inner
    d2 = jnp.maximum(d2, 0.0)
    k_tile = s2_ref[0] * _rho(kind, d2)           # (tm, tn)
    o_ref[...] += jnp.dot(k_tile, b_ref[...])     # (tm, r)


@partial(jax.jit, static_argnames=("kind", "tm", "tn"))
def kernel_mvm(xs, b, s2, noise, kind: int = RBF, tm: int = 64, tn: int = 64):
    """``(K + noise*I) @ b`` for ``K_ij = s2 * rho(||xs_i - xs_j||)``.

    Args:
      xs: ``(n, d)`` data already scaled by 1/lengthscale.
      b: ``(n, r)`` right-hand sides.
      s2: scalar outputscale.
      noise: scalar diagonal noise.
      kind: kernel family (RBF / MATERN12 / MATERN32 / MATERN52).
      tm, tn: row/column tile sizes (n must be divisible by both).

    Returns:
      ``(n, r)`` product.
    """
    n, d = xs.shape
    r = b.shape[1]
    assert n % tm == 0 and n % tn == 0, "n must be divisible by tile sizes"
    sq = jnp.sum(xs * xs, axis=1)
    s2_arr = jnp.reshape(s2, (1,)).astype(xs.dtype)
    grid = (n // tm, n // tn)
    out = pl.pallas_call(
        partial(_mvm_kernel, kind),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, d), lambda i, j: (i, 0)),   # row block of X
            pl.BlockSpec((tm,), lambda i, j: (i,)),       # row sq-norms
            pl.BlockSpec((tn, d), lambda i, j: (j, 0)),   # col block of X
            pl.BlockSpec((tn,), lambda i, j: (j,)),       # col sq-norms
            pl.BlockSpec((tn, r), lambda i, j: (j, 0)),   # B block
            pl.BlockSpec((1,), lambda i, j: (0,)),        # s2
        ],
        out_specs=pl.BlockSpec((tm, r), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, r), xs.dtype),
        interpret=True,
    )(xs, sq, xs, sq, b, s2_arr)
    return out + noise * b


def vmem_bytes_estimate(tm: int, tn: int, d: int, r: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set of one grid step (DESIGN.md section Perf).

    Counts the row block, column block, B block, distance tile, kernel tile
    and output accumulator (double-buffered inputs x2).
    """
    inputs = (tm * d + tn * d + tn * r + tm + tn) * dtype_bytes * 2  # double buffer
    scratch = (tm * tn) * dtype_bytes * 2  # d2 + k_tile
    accum = tm * r * dtype_bytes
    return inputs + scratch + accum


def mxu_utilization_estimate(tm: int, tn: int, d: int, r: int) -> float:
    """Fraction of the tile's FLOPs that are MXU matmuls (vs VPU pointwise)."""
    mxu = 2 * tm * tn * d + 2 * tm * tn * r
    vpu = 8 * tm * tn  # exp / mul / add chain per element (approx)
    return mxu / (mxu + vpu)

"""Pure-jnp oracle for the Pallas kernel MVM (the L1 correctness signal).

Materializes the kernel matrix densely — O(n^2) memory, fine at test sizes —
and multiplies. ``kernel_mvm`` must match this to float32 tolerance for all
kernel families, shapes and tile sizes.
"""

import jax.numpy as jnp

from . import kernel_mvm as km


def dense_kernel(xs, s2, noise, kind: int = km.RBF):
    """Dense ``K = s2 * rho(dist) + noise*I`` from scaled data ``xs``."""
    sq = jnp.sum(xs * xs, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * xs @ xs.T
    d2 = jnp.maximum(d2, 0.0)
    k = s2 * km._rho(kind, d2)
    return k + noise * jnp.eye(xs.shape[0], dtype=xs.dtype)


def kernel_mvm_ref(xs, b, s2, noise, kind: int = km.RBF):
    """Reference ``(K + noise I) @ b``."""
    return dense_kernel(xs, s2, noise, kind) @ b

"""AOT lowering: jax -> HLO *text* artifacts for the Rust/PJRT runtime.

HLO text (NOT ``MLIR``/``.serialize()`` protos) is the interchange format:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (written to --out, default ../artifacts):
  kernel_mvm_n{n}_d{d}_r{r}_k{kind}.hlo.txt
      inputs: xs (n,d) f32, b (n,r) f32, s2 () f32, noise () f32
      output: (n,r) f32                       [1-tuple]
  ciq_sqrt_n{n}_d{d}_q{q}_j{j}_k{kind}.hlo.txt
      inputs: xs (n,d), b (n,), shifts (q,), weights (q,), s2 (), noise ()
      output: (2n+1,) = [sqrt | invsqrt | max_residual]   [1-tuple]
plus manifest.json describing every artifact.

Run once via ``make artifacts``; Python never runs on the request path.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import kernel_mvm as km


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_kernel_mvm(n, d, r, kind, tm, tn):
    fn = lambda xs, b, s2, noise: model.batched_mvm(
        xs, b, s2, noise, kind=kind, use_pallas=True, tm=tm, tn=tn
    )
    return jax.jit(fn).lower(f32((n, d)), f32((n, r)), f32(()), f32(()))


def lower_ciq_sqrt(n, d, q, j, kind, tm, tn):
    fn = lambda xs, b, shifts, weights, s2, noise: model.ciq_sqrt(
        xs, b, shifts, weights, s2, noise,
        iters=j, kind=kind, use_pallas=True, tm=tm, tn=tn,
    )
    return jax.jit(fn).lower(
        f32((n, d)), f32((n,)), f32((q,)), f32((q,)), f32(()), f32(())
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--n", type=int, default=256, help="data size for artifacts")
    ap.add_argument("--d", type=int, default=2)
    ap.add_argument("--r", type=int, default=8, help="RHS batch for kernel_mvm artifact")
    ap.add_argument("--q", type=int, default=8)
    ap.add_argument("--iters", type=int, default=64)
    ap.add_argument("--tile", type=int, default=64)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest = {"artifacts": []}

    jobs = []
    for kind, kname in [(km.RBF, "rbf"), (km.MATERN52, "matern52")]:
        jobs.append(
            (
                f"kernel_mvm_n{args.n}_d{args.d}_r{args.r}_{kname}",
                lower_kernel_mvm(args.n, args.d, args.r, kind, args.tile, args.tile),
                {
                    "kind": "kernel_mvm",
                    "kernel": kname,
                    "n": args.n,
                    "d": args.d,
                    "r": args.r,
                    "inputs": [[args.n, args.d], [args.n, args.r], [], []],
                    "output": [args.n, args.r],
                },
            )
        )
    jobs.append(
        (
            f"ciq_sqrt_n{args.n}_d{args.d}_q{args.q}_j{args.iters}_rbf",
            lower_ciq_sqrt(args.n, args.d, args.q, args.iters, km.RBF, args.tile, args.tile),
            {
                "kind": "ciq_sqrt",
                "kernel": "rbf",
                "n": args.n,
                "d": args.d,
                "q": args.q,
                "iters": args.iters,
                "inputs": [[args.n, args.d], [args.n], [args.q], [args.q], [], []],
                "output": [2 * args.n + 1],
            },
        )
    )

    for name, lowered, meta in jobs:
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, name + ".hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        meta["file"] = name + ".hlo.txt"
        manifest["artifacts"].append(meta)
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=2)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()

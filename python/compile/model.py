"""Layer 2: the msMINRES-CIQ pipeline as a traced JAX program.

The recurrence mirrors ``rust/src/krylov/msminres.rs`` exactly, but is
vectorized over the Q shifts (leading axis) and runs a *fixed* number of
iterations J so the whole computation lowers to a single static HLO module:

  inputs : xs (n,d) scaled data, b (n,), shifts (Q,), weights (Q,),
           s2 (scalar), noise (scalar)
  output : concat([K^{1/2} b, K^{-1/2} b, max_residual])  -- shape (2n+1,)

Quadrature weights/shifts are *runtime inputs* (computed by the Rust
coordinator from its own Lanczos + elliptic-function code), so one artifact
serves any spectrum. The MVM inside the loop is the Layer-1 Pallas kernel.

Python only runs at build time: ``aot.py`` lowers these functions to HLO
text which ``rust/src/runtime`` loads and executes via PJRT.
"""

from functools import partial

import jax
import jax.numpy as jnp

from .kernels import kernel_mvm as km


def _msminres_step(mvm, carry, _):
    """One shared-Lanczos + per-shift-QR step (vectorized over shifts)."""
    (v, v_prev, beta_k, c1, s1, c2, s2g, phi, d_prev, d_prev2, x, shifts) = carry
    w = mvm(v) - beta_k * v_prev
    alpha = jnp.dot(v, w)
    w = w - alpha * v
    beta_next = jnp.linalg.norm(w)
    safe_beta = jnp.maximum(beta_next, 1e-30)

    eps = s2g * beta_k                      # (Q,)
    delta_bar = c2 * beta_k                 # (Q,)
    a = alpha + shifts                      # (Q,)
    delta = c1 * delta_bar + s1 * a
    gamma_bar = -s1 * delta_bar + c1 * a
    gamma = jnp.sqrt(gamma_bar**2 + beta_next**2)
    gamma = jnp.maximum(gamma, 1e-30)
    c = gamma_bar / gamma
    s = beta_next / gamma
    tau = c * phi
    phi_new = -s * phi

    d_new = (v[None, :] - delta[:, None] * d_prev - eps[:, None] * d_prev2) / gamma[:, None]
    x_new = x + tau[:, None] * d_new

    carry = (
        w / safe_beta,      # v_{k+1}
        v,                  # v_k becomes previous
        beta_next,
        c, s, c1, s1,       # rotate Givens history
        phi_new,
        d_new, d_prev,
        x_new,
        shifts,
    )
    return carry, None


@partial(
    jax.jit,
    static_argnames=("iters", "kind", "use_pallas", "tm", "tn"),
)
def ciq_sqrt(
    xs,
    b,
    shifts,
    weights,
    s2,
    noise,
    *,
    iters: int = 64,
    kind: int = km.RBF,
    use_pallas: bool = True,
    tm: int = 64,
    tn: int = 64,
):
    """msMINRES-CIQ: returns ``concat([K^{1/2}b, K^{-1/2}b, max_res])``."""
    n = xs.shape[0]
    q = shifts.shape[0]
    dtype = xs.dtype

    if use_pallas:
        def mvm(v):
            return km.kernel_mvm(xs, v[:, None], s2, noise, kind=kind, tm=tm, tn=tn)[:, 0]
    else:
        from .kernels import ref

        kmat = ref.dense_kernel(xs, s2, noise, kind)

        def mvm(v):
            return kmat @ v

    beta1 = jnp.linalg.norm(b)
    safe_beta1 = jnp.maximum(beta1, 1e-30)
    v0 = b / safe_beta1

    carry = (
        v0,
        jnp.zeros((n,), dtype),
        jnp.zeros((), dtype),                 # beta_k
        jnp.ones((q,), dtype),                # c1
        jnp.zeros((q,), dtype),               # s1
        jnp.ones((q,), dtype),                # c2
        jnp.zeros((q,), dtype),               # s2
        jnp.full((q,), 1.0, dtype) * beta1,   # phi
        jnp.zeros((q, n), dtype),             # d_prev
        jnp.zeros((q, n), dtype),             # d_prev2
        jnp.zeros((q, n), dtype),             # x
        shifts.astype(dtype),
    )
    carry, _ = jax.lax.scan(partial(_msminres_step, mvm), carry, None, length=iters)
    phi = carry[7]
    x = carry[10]

    inv_sqrt = weights.astype(dtype) @ x          # (n,)
    sqrt = mvm(inv_sqrt)                          # K^{1/2} b = K K^{-1/2} b
    max_res = jnp.max(jnp.abs(phi)) / safe_beta1
    return jnp.concatenate([sqrt, inv_sqrt, max_res[None]])


@partial(jax.jit, static_argnames=("kind", "use_pallas", "tm", "tn"))
def batched_mvm(xs, b, s2, noise, *, kind: int = km.RBF, use_pallas: bool = True, tm: int = 64, tn: int = 64):
    """Standalone batched kernel MVM artifact: ``(K + noise I) B``."""
    if use_pallas:
        return km.kernel_mvm(xs, b, s2, noise, kind=kind, tm=tm, tn=tn)
    from .kernels import ref

    return ref.kernel_mvm_ref(xs, b, s2, noise, kind)

"""L2 correctness: the jax msMINRES-CIQ pipeline vs dense linear algebra."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import kernel_mvm as km
from compile.kernels import ref


def _quadrature(q, lam_min, lam_max):
    """Hale et al. weights/shifts via scipy (mirror of rust/src/quadrature)."""
    from scipy.special import ellipj, ellipk

    k2 = lam_min / lam_max
    kp2 = 1.0 - k2
    big_kp = ellipk(kp2)
    u = (np.arange(1, q + 1) - 0.5) / q
    sn, cn, dn, _ = ellipj(u * big_kp, kp2)
    shifts = lam_min * (sn / cn) ** 2
    weights = 2.0 * np.sqrt(lam_min) * big_kp * dn / (np.pi * q * cn**2)
    return shifts.astype(np.float32), weights.astype(np.float32)


def _setup(n=64, d=2, seed=0, noise=0.5):
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.normal(size=(n, d)), dtype=jnp.float32)
    b = jnp.asarray(rng.normal(size=(n,)), dtype=jnp.float32)
    kmat = np.asarray(ref.dense_kernel(xs, 1.0, noise, km.RBF), dtype=np.float64)
    evals = np.linalg.eigvalsh(kmat)
    shifts, weights = _quadrature(8, float(evals[0]) * 0.9, float(evals[-1]) * 1.1)
    return xs, b, kmat, shifts, weights


def _exact_sqrt_mvm(kmat, b, power):
    evals, evecs = np.linalg.eigh(kmat)
    return evecs @ (np.maximum(evals, 1e-12) ** power * (evecs.T @ np.asarray(b, np.float64)))


@pytest.mark.parametrize("use_pallas", [False, True])
def test_ciq_sqrt_matches_eigh(use_pallas):
    xs, b, kmat, shifts, weights = _setup()
    out = model.ciq_sqrt(
        xs, b, jnp.asarray(shifts), jnp.asarray(weights), 1.0, 0.5,
        iters=80, kind=km.RBF, use_pallas=use_pallas, tm=32, tn=32,
    )
    n = xs.shape[0]
    sqrt, inv_sqrt, res = np.asarray(out[:n]), np.asarray(out[n : 2 * n]), float(out[-1])
    exact_sqrt = _exact_sqrt_mvm(kmat, b, 0.5)
    exact_inv = _exact_sqrt_mvm(kmat, b, -0.5)
    rel_s = np.linalg.norm(sqrt - exact_sqrt) / np.linalg.norm(exact_sqrt)
    rel_i = np.linalg.norm(inv_sqrt - exact_inv) / np.linalg.norm(exact_inv)
    assert rel_s < 5e-3, f"sqrt rel err {rel_s}"
    assert rel_i < 5e-3, f"invsqrt rel err {rel_i}"
    assert res < 1e-3, f"residual {res}"


def test_residual_decreases_with_iters():
    xs, b, _, shifts, weights = _setup(seed=1)
    res = []
    for j in [4, 16, 64]:
        out = model.ciq_sqrt(
            xs, b, jnp.asarray(shifts), jnp.asarray(weights), 1.0, 0.5,
            iters=j, kind=km.RBF, use_pallas=False,
        )
        res.append(float(out[-1]))
    assert res[2] < res[1] < res[0], f"residuals not decreasing: {res}"


def test_sqrt_squares_to_mvm():
    # K^{1/2}(K^{1/2} b) == K b
    xs, b, kmat, shifts, weights = _setup(seed=2)
    args = (jnp.asarray(shifts), jnp.asarray(weights), 1.0, 0.5)
    n = xs.shape[0]
    out1 = model.ciq_sqrt(xs, b, *args, iters=80, use_pallas=False)
    half = out1[:n]
    out2 = model.ciq_sqrt(xs, half, *args, iters=80, use_pallas=False)
    full = np.asarray(out2[:n], dtype=np.float64)
    exact = kmat @ np.asarray(b, np.float64)
    rel = np.linalg.norm(full - exact) / np.linalg.norm(exact)
    assert rel < 2e-2, f"K^1/2 K^1/2 b vs K b rel err {rel}"


def test_batched_mvm_matches_ref():
    rng = np.random.default_rng(5)
    xs = jnp.asarray(rng.normal(size=(64, 3)), dtype=jnp.float32)
    b = jnp.asarray(rng.normal(size=(64, 8)), dtype=jnp.float32)
    out = model.batched_mvm(xs, b, 1.2, 0.3, kind=km.MATERN52, use_pallas=True, tm=32, tn=32)
    expect = ref.kernel_mvm_ref(xs, b, jnp.float32(1.2), jnp.float32(0.3), km.MATERN52)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=3e-4, atol=3e-4)

"""AOT lowering tests: the HLO-text interchange contract with the Rust side.

The Rust runtime parses artifacts with `HloModuleProto::from_text_file`, so
the emitted text must be genuine HLO module text (not StableHLO/MLIR), with
the agreed parameter arity and a single tuple result.
"""

import jax.numpy as jnp

from compile import aot


def test_kernel_mvm_lowering_emits_hlo_text():
    lowered = aot.lower_kernel_mvm(n=64, d=2, r=4, kind=0, tm=32, tn=32)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), "must be HLO text, not MLIR"
    assert "ENTRY" in text
    # 4 parameters: xs, b, s2, noise
    for i in range(4):
        assert f"parameter({i})" in text, f"missing parameter({i})"
    assert "parameter(4)" not in text
    # output shape (n, r) appears as the root tuple element
    assert "f32[64,4]" in text


def test_ciq_lowering_has_fixed_iteration_structure():
    lowered = aot.lower_ciq_sqrt(n=64, d=2, q=4, j=8, kind=0, tm=32, tn=32)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    # 6 parameters: xs, b, shifts, weights, s2, noise
    for i in range(6):
        assert f"parameter({i})" in text
    # output: concat([sqrt, invsqrt, residual]) of length 2n+1
    assert "f32[129]" in text, "expected 2n+1 = 129 output"
    # the fixed-J loop lowers to a while op over the scan
    assert "while" in text, "msMINRES scan should lower to an HLO while loop"


def test_artifact_roundtrips_through_fresh_lowering():
    # same inputs => identical HLO text (determinism of the AOT pipeline)
    t1 = aot.to_hlo_text(aot.lower_kernel_mvm(32, 2, 2, 0, 16, 16))
    t2 = aot.to_hlo_text(aot.lower_kernel_mvm(32, 2, 2, 0, 16, 16))
    assert t1 == t2

"""L1 correctness: Pallas kernel vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, tile sizes, kernel families and dtypes; explicit
tests pin down the known values.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import kernel_mvm as km
from compile.kernels import ref

KINDS = [km.RBF, km.MATERN12, km.MATERN32, km.MATERN52]


def _data(n, d, r, seed):
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.normal(size=(n, d)), dtype=jnp.float32)
    b = jnp.asarray(rng.normal(size=(n, r)), dtype=jnp.float32)
    return xs, b


@pytest.mark.parametrize("kind", KINDS)
def test_matches_ref_all_kernels(kind):
    xs, b = _data(128, 3, 4, seed=kind)
    out = km.kernel_mvm(xs, b, 1.3, 0.05, kind=kind, tm=32, tn=32)
    expect = ref.kernel_mvm_ref(xs, b, 1.3, 0.05, kind)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=5e-3, atol=5e-3)


@settings(max_examples=25, deadline=None)
@given(
    n_tiles=st.integers(min_value=1, max_value=4),
    tile=st.sampled_from([8, 16, 32]),
    d=st.integers(min_value=1, max_value=5),
    r=st.integers(min_value=1, max_value=6),
    kind=st.sampled_from(KINDS),
    s2=st.floats(min_value=0.1, max_value=5.0),
    noise=st.floats(min_value=0.0, max_value=1.0),
)
def test_hypothesis_shape_sweep(n_tiles, tile, d, r, kind, s2, noise):
    n = n_tiles * tile
    xs, b = _data(n, d, r, seed=n * 7 + d * 3 + r)
    out = km.kernel_mvm(xs, b, s2, noise, kind=kind, tm=tile, tn=tile)
    expect = ref.kernel_mvm_ref(xs, b, jnp.float32(s2), jnp.float32(noise), kind)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=3e-3, atol=3e-3)


def test_tile_size_invariance():
    xs, b = _data(96, 2, 3, seed=11)
    outs = [
        np.asarray(km.kernel_mvm(xs, b, 1.0, 0.1, kind=km.RBF, tm=tm, tn=tn))
        for (tm, tn) in [(8, 8), (16, 32), (96, 96), (32, 8)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)


def test_identity_limit():
    # At huge distance (tiny lengthscale scaled-out), K -> s2*I on diagonal
    n = 32
    xs = jnp.asarray(np.arange(n, dtype=np.float32)[:, None] * 100.0)
    b = jnp.eye(n, dtype=jnp.float32)[:, :4]
    out = km.kernel_mvm(xs, b, 2.0, 0.5, kind=km.RBF, tm=16, tn=16)
    expect = 2.5 * b  # (s2 + noise) * I @ b
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-6)


def test_constant_vector_rowsums():
    xs, _ = _data(64, 2, 1, seed=3)
    ones = jnp.ones((64, 1), dtype=jnp.float32)
    out = km.kernel_mvm(xs, ones, 1.0, 0.0, kind=km.RBF, tm=32, tn=32)
    k = ref.dense_kernel(xs, 1.0, 0.0, km.RBF)
    np.testing.assert_allclose(np.asarray(out)[:, 0], np.asarray(k.sum(axis=1)), rtol=1e-4)


def test_vmem_estimate_within_budget():
    # default tiles must fit comfortably in 16 MB VMEM
    assert km.vmem_bytes_estimate(64, 64, 4, 8) < 16 * 2**20
    assert km.vmem_bytes_estimate(256, 256, 8, 16) < 16 * 2**20
    # MXU share should dominate for matmul-heavy tiles
    assert km.mxu_utilization_estimate(128, 128, 8, 8) > 0.6
